"""Cache-affinity serving router: data-diffusion dispatch on the request path.

Each model replica is one of the paper's *executors* with a *transient
store*: its KV-prefix blocks, LoRA adapters, or weight shards are the data
objects, accounted by ``core.cache.Cache`` and published to the
``CentralizedIndex`` so the dispatcher knows who holds what.  Incoming
requests are the work items — a request names the objects it needs
(``RoutedRequest.objects``) and the generic ``DataAwareDispatcher`` routes it
with the paper's five policies, unchanged.  The ``DynamicResourceProvisioner``
watches the wait queue and grows/shrinks the replica pool exactly as Section
3.3 prescribes for executors.

The router is transport-agnostic and clock-agnostic: callers pass ``now``
explicitly (the serving loop passes wall-clock, the routing benchmark passes
virtual time), receive ``Assignment`` batches to execute however they like,
and report completions back via ``complete`` — which triggers the Falkon
pickup path (phase 2) for the freed replica.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from ..core.dispatch import POLICIES, DataAwareDispatcher
from ..core.index import CacheLocationIndex, CentralizedIndex
from ..dispatch_vec import VectorizedDispatcher
from ..core.provisioner import DynamicResourceProvisioner, ProvisionRequest
from ..core.store import BandwidthResource
from ..core.task import ExecutorState
from ..diffusion.prefetch import Prefetcher
from ..diffusion.tiers import TieredStore, TierSpec, default_tier_weights
from ..diffusion.transfer import TransferEngine
from ..index.warmstart import WarmStartReport, WarmStartStats, clone_hottest
from ..obs.registry import P2Quantile
from .admission import AdmissionController, AdmissionVerdict
from .chaos import FaultStats
from .fault_tolerance import HeartbeatMonitor

__all__ = ["POLICIES", "AdmissionController", "AdmissionVerdict",
           "Assignment", "CacheAffinityRouter", "LatencyReservoir",
           "ReplicaStore", "RoutedRequest", "RouterStats"]


@dataclass
class RoutedRequest:
    """A unit of serving work and the data objects it wants to find cached."""

    request_id: int
    objects: Tuple[str, ...]            # KV-prefix blocks / adapters / shards
    payload: Any = None                 # opaque to the router
    submit_time_s: float = 0.0
    dispatch_time_s: Optional[float] = None
    finish_time_s: Optional[float] = None
    replica: Optional[str] = None
    hits: int = 0                       # objects found in the replica's store
    misses: int = 0                     # objects fetched/recomputed on demand
    # Where each object was resolved: a tier name ("hbm"/"dram"/...), a
    # transfer source ("peer:<name>"/"persistent"), filled by the router.
    sources: Dict[str, str] = field(default_factory=dict)
    restore_cost_s: float = 0.0         # swap-in + transfer time still to pay
    # Multi-tenant admission plane: the paying tenant ("" = the implicit
    # "default" account) and an optional absolute deadline — under overload
    # the admission controller sheds past-deadline requests before fresh
    # ones (runtime/admission.py).
    tenant: str = ""
    deadline_s: Optional[float] = None

    @property
    def key(self) -> int:
        return self.request_id

    @property
    def response_time_s(self) -> Optional[float]:
        if self.finish_time_s is None:
            return None
        return self.finish_time_s - self.submit_time_s


class ReplicaStore:
    """One replica's transient store: a tier stack + index publication.

    Built on ``diffusion.tiers.TieredStore``: the store holds object *names
    and sizes* only (the replica owns the actual KV tensors); presence per
    tier is mirrored into the centralized index so phase-1 routing sees it,
    mirroring the executor->index update messages of Section 3.1.1.  With a
    single tier this is exactly the flat hit-or-admit store of PR 1; with an
    HBM + host-DRAM stack, eviction from HBM *demotes* the KV prefix to DRAM
    instead of dropping it, so a later "miss" is a cheap swap-in rather than
    a full prefill replay.
    """

    def __init__(
        self,
        name: str,
        capacity_bytes: float,
        index: CacheLocationIndex,
        eviction: str = "lru",
        rng=None,
        on_evict: Optional[Callable[[str, str], None]] = None,
        tier_specs: Optional[Sequence[TierSpec]] = None,
        nic_bw_bytes_per_s: float = float("inf"),
    ):
        self.name = name
        self.index = index
        if tier_specs is None:
            tier_specs = [TierSpec("hbm", capacity_bytes, eviction=eviction)]

        def _dropped(obj: str, size: float) -> None:
            if on_evict is not None:
                on_evict(name, obj)   # let the owner free the real payload

        self.tiers = TieredStore(name, tier_specs, index=index,
                                 nic_bw_bytes_per_s=nic_bw_bytes_per_s,
                                 on_drop=_dropped, rng=rng)

    def __contains__(self, obj: str) -> bool:
        return obj in self.tiers

    def contains(self, obj: str) -> bool:
        return obj in self.tiers

    @property
    def top_tier(self) -> str:
        return self.tiers.top_tier

    def tier_of(self, obj: str) -> Optional[str]:
        return self.tiers.tier_of(obj)

    def access(self, obj: str) -> Optional[str]:
        """Hit test + recency update; returns the tier the object was found
        in (None on miss).  Lower-tier hits promote toward HBM."""
        return self.tiers.access(obj)

    def admit(self, obj: str, size_bytes: float) -> List[str]:
        """On-demand caching: object materialized here; returns full drops."""
        return self.tiers.admit(obj, size_bytes)

    def drop(self, obj: str) -> None:
        self.tiers.drop(obj)

    def publish(self) -> Tuple[int, int]:
        """Full-snapshot re-sync (recovery path after index drift/loss)."""
        return self.index.publish(self.name, self.tiers.contents())


@dataclass
class Assignment:
    """A routed batch: run these requests on this replica, then complete()."""

    replica: str
    requests: List[RoutedRequest]


class LatencyReservoir:
    """Fixed-size ring buffer of latency samples + streaming lifetime stats.

    ``RouterStats.latencies_s`` grew one float per request forever — a leak
    at millions-of-users scale.  The reservoir keeps the most recent
    ``maxlen`` samples; **sorted percentiles are exact within that window
    only** (they forget everything older than ``maxlen`` samples — use
    ``window_percentile_s`` / the ``win_``-prefixed metric names, which say
    so).  The streaming aggregates — ``total`` / ``sum`` / ``min`` /
    ``max`` / ``mean_s`` and the P² quantile estimates surfaced as
    ``est_p50_s`` / ``est_p99_s`` — are lifetime-true: they survive ring
    wraps, so the mean and tail latency of a long-running server are not
    silently truncated to its last 4096 requests.  It is list-like where
    the stats code needs it (append / len / iterate).
    """

    __slots__ = ("maxlen", "_buf", "_next", "total", "sum", "min", "max",
                 "_p2_50", "_p2_99")

    def __init__(self, maxlen: int = 4096):
        self.maxlen = int(maxlen)
        self._buf: List[float] = []
        self._next = 0          # ring write cursor once the buffer is full
        self.total = 0          # lifetime sample count (not window-bounded)
        self.sum = 0.0          # lifetime sum: mean survives ring wraps
        self.min = math.inf     # lifetime extremes
        self.max = -math.inf
        self._p2_50 = P2Quantile(0.50)
        self._p2_99 = P2Quantile(0.99)

    def append(self, x: float) -> None:
        self.total += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        self._p2_50.observe(x)
        self._p2_99.observe(x)
        if len(self._buf) < self.maxlen:
            self._buf.append(x)
        else:
            self._buf[self._next] = x
            self._next = (self._next + 1) % self.maxlen

    @property
    def mean_s(self) -> float:
        """Lifetime mean (every sample ever appended, not just the window)."""
        return self.sum / self.total if self.total else 0.0

    def snapshot(self) -> Dict[str, float]:
        out = {
            "count": float(self.total),
            "sum_s": self.sum,
            "mean_s": self.mean_s,
            "window": float(len(self._buf)),
            "est_p50_s": self._p2_50.value,
            "est_p99_s": self._p2_99.value,
        }
        if self.total:
            out["min_s"] = self.min
            out["max_s"] = self.max
        return out

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[float]:
        return iter(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)


@dataclass
class RouterStats:
    routed: int = 0
    completed: int = 0
    object_hits: int = 0
    object_misses: int = 0
    scale_ups: int = 0
    scale_downs: int = 0
    latencies_s: LatencyReservoir = field(default_factory=LatencyReservoir)
    # diffusion-plane accounting
    hits_by_tier: Dict[str, int] = field(default_factory=dict)
    restore_time_s: float = 0.0          # total swap-in + transfer time charged
    bytes_from_persistent: float = 0.0   # flat mode only; engine tracks tiered
    # Batched-drain staleness the dispatcher's admission overlay cannot see:
    # replay-time events where the store's actual evolution diverged from
    # the frozen snapshot the batch was decided on — a hit whose object an
    # earlier admission's eviction cascade dropped, a dup-miss re-dropped
    # before its replay position, or an assumed admission that failed to
    # stick (pass-through object).  Counted, never silent; the dispatcher's
    # own counters live in ``dispatcher.stats.batch_stale_decisions`` /
    # ``batch_emulated_decisions``.
    stale_snapshot_drops: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.object_hits + self.object_misses
        return self.object_hits / total if total else 0.0

    @property
    def mean_latency_s(self) -> float:
        """Lifetime mean response time (survives the reservoir's ring wraps)."""
        return self.latencies_s.mean_s

    def window_percentile_s(self, pct: float) -> float:
        """Percentile over the reservoir's retained window ONLY.

        Exact for the most recent ``latencies_s.maxlen`` samples and blind
        to everything older — a *window* p99, not a lifetime p99.  Callers
        printing it should label it ``win_p99`` (the benches do); for a
        lifetime tail that survives ring wraps, read the reservoir's P²
        estimates (``latency.est_p50_s`` / ``latency.est_p99_s``).
        """
        if not self.latencies_s:
            return 0.0
        xs = sorted(self.latencies_s)
        i = min(len(xs) - 1, max(0, math.ceil(pct / 100.0 * len(xs)) - 1))
        return xs[i]

    # Back-compat name; same window-only semantics as window_percentile_s.
    latency_percentile_s = window_percentile_s

    @property
    def p50_s(self) -> float:
        return self.window_percentile_s(50.0)

    @property
    def p99_s(self) -> float:
        return self.window_percentile_s(99.0)

    def snapshot(self) -> Dict[str, float]:
        """Registry-source view (prefixed ``router.`` when adopted)."""
        from ..obs.registry import stats_snapshot
        out = stats_snapshot(self, props=("hit_rate", "mean_latency_s"))
        for k, v in self.latencies_s.snapshot().items():
            out[f"latency.{k}"] = v
        out["latency.win_p50_s"] = self.window_percentile_s(50.0)
        out["latency.win_p99_s"] = self.window_percentile_s(99.0)
        return out


class CacheAffinityRouter:
    """Routes requests to replicas with the paper's data-aware policies.

    Host integration points:
      * ``spawn_replica(name)``  — DRP scaled up: build the actual replica
        (load weights, warm compile) before it starts receiving work.
      * ``stop_replica(name)``   — DRP idle-released the replica.
    Both callbacks are optional; pure-accounting users (benchmarks, tests)
    can drive the router without a model behind it.
    """

    def __init__(
        self,
        policy: str = "good-cache-compute",
        *,
        window: int = 256,
        cpu_util_threshold: float = 0.8,
        max_object_replicas: int = 4,
        replica_capacity_bytes: float = float("inf"),
        eviction: str = "lru",
        object_size_fn: Callable[[str], float] = lambda obj: 1.0,
        index: Optional[CacheLocationIndex] = None,
        provisioner: Optional[DynamicResourceProvisioner] = None,
        spawn_replica: Optional[Callable[[str], None]] = None,
        stop_replica: Optional[Callable[[str], None]] = None,
        on_object_evicted: Optional[Callable[[str, str], None]] = None,
        pickup_batch: int = 1,
        gcc_delay_tier_floor: float = 0.0,
        # ---- tiered data-diffusion plane (None = flat PR-1 behavior) ----
        tier_specs: Optional[Sequence[TierSpec]] = None,
        tier_weights: Optional[Dict[str, float]] = None,
        persistent_bw_bytes_per_s: float = float("inf"),
        nic_bw_bytes_per_s: float = float("inf"),
        transfer_max_inflight: int = 8,
        use_peer_transfer: bool = True,
        prefetch_depth: int = 0,
        # ---- payload plane: "real" makes the transfer engine copy actual
        # bytes through the stores' payload backends (built per replica by
        # payload_factory(name)); "modeled" keeps bookkeeping-only transfers.
        # Decisions are bit-identical in both modes.  ----
        transfer_payload: str = "modeled",
        payload_factory: Optional[Callable[[str], Any]] = None,
        # ---- replica warm-start (index plane): clone this many of the
        # hottest index objects into each DRP-provisioned replica ----
        warmstart_objects: int = 0,
        warmstart_admit_tier: int = 1,
        # Objects at or above this (decayed) heat bypass warmstart_admit_tier
        # and clone straight into HBM (tier 0); None disables.
        warmstart_hbm_heat: Optional[float] = None,
        # ---- dispatch engine: "reference" (pure-Python golden semantics)
        # or "vectorized" (repro.dispatch_vec — same decisions, array-backed
        # scoring).  With ``batch_drain=False`` the router loops per-decision
        # ``notify()``; with ``batch_drain=True`` it drains every free
        # replica from one ``notify_batch()`` scan against a frozen presence
        # snapshot (tier promotions deferred to a per-batch delta, missed
        # objects admitted through one batched transfer resolution) ----
        dispatcher_impl: str = "reference",
        batch_drain: bool = False,
        # Decision-parity escape hatch: record "request_id->replica" for
        # every started request so seeded streams can assert batched ≡
        # looped assignment sequences (bench_serve_batch gates on it).
        log_assignments: bool = False,
        # ---- observability plane (repro.obs): None (default) is the no-op
        # stub path — no spans are allocated and no metric work runs.  An
        # Observability instance adopts every stats island into its
        # registry and records the per-request span chain (dispatch ->
        # transfer -> completion, batch drains as structural spans) into
        # its trace ring.  Decisions are identical either way.  ----
        obs: Optional[Any] = None,
        # ---- robustness plane (failure domain).  All default OFF: with no
        # timeout, no chaos injector, and no heartbeat monitor the serving
        # path is bit-identical to the pre-robustness router (the chaos
        # parity bench gates on it).
        #   transfer_timeout_s     — per-flight peer-copy deadline; a peer
        #       source whose copy_time exceeds it is treated as dead and the
        #       fetch retries against the next-cheapest source.
        #   transfer_max_retries   — retry budget per fetch before the
        #       resolution degrades unconditionally to persistent storage.
        #   chaos                  — runtime.chaos.ChaosInjector; strict
        #       no-op while its schedule is idle.
        #   heartbeat_timeout_s    — enables the HeartbeatMonitor liveness
        #       source (None = no monitor); missed beats crash the replica
        #       through fail_replica, EWMA stragglers lose dispatch ties.
        transfer_timeout_s: Optional[float] = None,
        transfer_max_retries: int = 3,
        transfer_retry_backoff_s: float = 0.05,
        #   transfer_retry_jitter_s — deterministic (seeded) jitter fraction
        #       on the retry backoff ladder so a mass failover's synchronized
        #       retries don't thundering-herd one surviving source; 0.0
        #       (default) keeps the exact legacy ladder.
        transfer_retry_jitter_frac: float = 0.0,
        transfer_jitter_seed: int = 0,
        chaos: Optional[Any] = None,
        heartbeat_timeout_s: Optional[float] = None,
        straggler_factor: float = 2.0,
        # ---- overload robustness plane (multi-tenant admission).  None
        # (default) is a strict no-op: enqueue dispatches exactly as before
        # and returns ACCEPTED unconditionally.  An AdmissionController
        # turns enqueue into the backpressure contract (ACCEPTED / DEGRADED
        # / REJECTED), sheds deadline-expired and over-share work under
        # overload (lowest credit first), biases pick-item dispatch ties by
        # tenant share, and caps per-tenant tier bytes on every store.
        admission: Optional[AdmissionController] = None,
    ):
        self.index = index if index is not None else CentralizedIndex()
        self.tier_specs = list(tier_specs) if tier_specs is not None else None
        if tier_weights is None and self.tier_specs is not None:
            tier_weights = default_tier_weights(self.tier_specs)
        if dispatcher_impl not in ("reference", "vectorized"):
            raise ValueError(f"unknown dispatcher_impl {dispatcher_impl!r}")
        engine_cls = (VectorizedDispatcher if dispatcher_impl == "vectorized"
                      else DataAwareDispatcher)
        self.dispatcher = engine_cls(
            policy=policy,
            window=window,
            cpu_util_threshold=cpu_util_threshold,
            max_replicas=max_object_replicas,
            index=self.index,
            tier_weights=tier_weights,
            gcc_delay_tier_floor=gcc_delay_tier_floor,
            # Batched drains decide against a frozen snapshot; the looped
            # path admits each assignment's objects before the next
            # decision.  Emulating that admission evolution inside the scan
            # keeps batched ≡ looped bit-exact even when the replication
            # cap binds mid-burst (stats.batch_emulated_decisions counts
            # every decision the overlay corrected).
            emulate_batch_admissions=batch_drain,
        )
        self.replica_capacity_bytes = replica_capacity_bytes
        self.eviction = eviction
        self.object_size_fn = object_size_fn
        self.drp = provisioner
        self.admission = admission
        self._payload_factory = payload_factory
        self._spawn = spawn_replica
        self._stop = stop_replica
        self._on_object_evicted = on_object_evicted
        self.pickup_batch = pickup_batch
        self.nic_bw_bytes_per_s = nic_bw_bytes_per_s
        self.stores: Dict[str, ReplicaStore] = {}
        # The transfer engine + prefetcher exist only in tiered mode; the
        # flat path keeps PR-1's zero-cost admit (no bandwidth model).
        self.engine: Optional[TransferEngine] = None
        self.prefetcher: Optional[Prefetcher] = None
        if self.tier_specs is not None:
            self.persistent_link = BandwidthResource(
                "persistent.link", persistent_bw_bytes_per_s)
            self.engine = TransferEngine(
                self.index, self.persistent_link,
                max_inflight=transfer_max_inflight, use_peers=use_peer_transfer,
                payload=transfer_payload,
                timeout_s=transfer_timeout_s,
                max_retries=transfer_max_retries,
                retry_backoff_s=transfer_retry_backoff_s,
                retry_jitter_frac=transfer_retry_jitter_frac,
                jitter_seed=transfer_jitter_seed,
                chaos=chaos)
            if prefetch_depth > 0:
                self.prefetcher = Prefetcher(self.engine, object_size_fn)
        self.prefetch_depth = prefetch_depth
        self.warmstart_objects = warmstart_objects
        self.warmstart_admit_tier = warmstart_admit_tier
        self.warmstart_hbm_heat = warmstart_hbm_heat
        self.warmstart = WarmStartStats()
        self.batch_drain = batch_drain
        self.assignment_log: Optional[List[str]] = [] if log_assignments else None
        self._requests: Dict[int, RoutedRequest] = {}   # in flight, by id
        self._idle_since: Dict[str, Optional[float]] = {}
        self._pending_provisions: List[ProvisionRequest] = []
        self._next_replica = 0
        self.stats = RouterStats()
        # Failure-domain accounting island.  Always allocated (counters are
        # cheap); the chaos injector, when attached, adopts it so injection
        # and recovery counters land in one ``faults.*`` snapshot.
        self.faults = FaultStats()
        self.chaos = chaos
        if chaos is not None:
            chaos.bind(self.faults)
            if hasattr(self.index, "rpc_loss"):
                # Sharded coherence wire: chaos may drop update RPCs.
                self.index.rpc_loss = chaos.rpc_lost
        self.monitor: Optional[HeartbeatMonitor] = (
            HeartbeatMonitor(heartbeat_timeout_s, straggler_factor)
            if heartbeat_timeout_s is not None else None)
        # Poisoned copies awaiting re-fetch: recovery is deferred to tick()
        # so a corruption detected mid-read never mutates the store it was
        # detected inside of (re-entrancy hazard).
        self._corrupt_refetch: List[Tuple[str, str]] = []
        # Observability stub path: hooks test `self._trace is not None` /
        # `self._perf is not None` once each — with obs=None nothing is
        # allocated or computed on the hot path (tests/test_obs.py asserts
        # the disabled path records zero spans).
        self.obs = obs
        self._trace = obs.trace if obs is not None else None
        self._perf = obs.perf if obs is not None else None
        self._slo = getattr(obs, "slo", None) if obs is not None else None
        if obs is not None:
            self._register_obs_sources(obs)

    def _register_obs_sources(self, obs: Any) -> None:
        """Adopt every stats island this router owns into the obs registry.

        Each island stays authoritative (the registry reads ``snapshot()``
        lazily at collect time); prefixes are the stable plane names
        ``docs/metrics.md`` catalogues."""
        reg = obs.registry
        reg.register_source("router", self.stats)
        reg.register_source("dispatch", self.dispatcher.stats)
        reg.register_source("warmstart", self.warmstart)
        reg.register_source("faults", self.faults)
        if self.engine is not None:
            reg.register_source("transfer", self.engine.stats)
            self.engine.trace = self._trace     # flight/payload spans
        if self.prefetcher is not None:
            reg.register_source("prefetch", self.prefetcher.stats)
        bus = getattr(self.index, "bus", None)
        if bus is not None and hasattr(bus, "stats"):
            reg.register_source("coherence", bus.stats)
        reg.register_callable("tiers", self._tiers_snapshot)
        if self.admission is not None:
            reg.register_source("admission", self.admission)
            reg.register_callable("tenant", self._tenant_snapshot)

    def _tiers_snapshot(self) -> Dict[str, float]:
        """Fleet aggregate of every replica store's per-tier counters."""
        out: Dict[str, float] = {}
        for store in self.stores.values():
            for k, v in store.tiers.snapshot().items():
                out[k] = out.get(k, 0.0) + v
        return out

    def _tenant_snapshot(self) -> Dict[str, float]:
        """The ``tenant.*`` island: per-tenant accounts, with resident
        tier bytes refreshed from the stores' quota accounting (lazy —
        snapshot-time only, nothing on the request path)."""
        adm = self.admission
        totals: Dict[str, float] = {}
        for store in self.stores.values():
            for t, b in store.tiers.tenant_bytes.items():
                totals[t] = totals.get(t, 0.0) + b
        for name, st in adm.tenants.items():
            st.tier_bytes = totals.get(name, 0.0)
        return adm.tenants_snapshot()

    @property
    def policy(self) -> str:
        return self.dispatcher.policy

    # ------------------------------------------------------------- replicas
    def add_replica(
        self,
        name: Optional[str] = None,
        capacity_bytes: Optional[float] = None,
        eviction: Optional[str] = None,
        now: Optional[float] = None,
    ) -> str:
        if name is None:
            name = f"replica{self._next_replica}"
            self._next_replica += 1
        self.stores[name] = ReplicaStore(
            name,
            capacity_bytes if capacity_bytes is not None else self.replica_capacity_bytes,
            self.index,
            eviction=eviction or self.eviction,
            on_evict=self._on_object_evicted,
            tier_specs=self.tier_specs,
            nic_bw_bytes_per_s=self.nic_bw_bytes_per_s,
        )
        if self._payload_factory is not None:
            backend = self._payload_factory(name)
            if hasattr(backend, "on_corruption"):
                # Degrade-don't-die: a poisoned spill chunk drops the copy
                # and queues a re-fetch instead of failing the request.
                backend.on_corruption = (
                    lambda obj, _n=name: self._note_corruption(_n, obj))
            self.stores[name].tiers.attach_payload(backend)
        if self.admission is not None:
            quotas = self.admission.store_quotas()
            if quotas:
                # One tenant's working set cannot evict above its share:
                # the store refuses placements past the tenant's byte cap.
                self.stores[name].tiers.set_tenant_quotas(
                    quotas, self.admission.tenant_of_object)
        if self.engine is not None:
            self.engine.register(name, self.stores[name].tiers)
        if self.monitor is not None:
            self.monitor.register(name, now)
        self.dispatcher.register_executor(name)
        # idle clock starts at first observation (None), NOT at 0.0 — under
        # wall-clock time a 0.0 stamp would make a fresh replica look idle
        # since the epoch and releasable on the very next tick.
        self._idle_since[name] = None
        return name

    def remove_replica(self, name: str) -> None:
        self.dispatcher.deregister_executor(name)   # drops its index entries
        if self.engine is not None:
            self.engine.deregister(name)
        if self.monitor is not None:
            self.monitor.forget(name)
        if self.chaos is not None:
            self.chaos.forget(name)
        self.stores.pop(name, None)
        self._idle_since.pop(name, None)

    def fail_replica(self, name: str, now: Optional[float] = None
                     ) -> List[RoutedRequest]:
        """Replica crash — distinct from ``remove_replica`` (graceful
        scale-down, which assumes the replica drained its work first).

        Crash semantics, in order:
          1. every in-flight request dispatched to the dead replica is
             orphaned: reset to undispatched state and re-submitted exactly
             once (the ``_finish`` guard drops any stale completion the dead
             replica might still report, so accounting stays at-most-once);
          2. the index quarantines immediately — live entries drop *and*
             queued coherence ops naming the dead executor are purged, so a
             delayed "add" can never resurrect a claim on a crashed store;
          3. the transfer engine evacuates: inbound flights cancel (slots/ω
             released, single-flight joiners notified of terminal failure),
             outbound flights fail over to the next-cheapest source;
          4. the DRP back-fills the lost capacity 1:1 (the replacement
             warm-starts from surviving peers via the usual scale-up path).

        Returns the orphaned requests (already re-queued).
        """
        now = time.monotonic() if now is None else now
        if name not in self.stores:
            return []
        self.faults.replicas_failed += 1
        if self.monitor is not None:
            self.monitor.forget(name)
        if self.chaos is not None:
            self.chaos.forget(name)
        orphans = [r for r in self._requests.values()
                   if r.replica == name and r.finish_time_s is None
                   and r.dispatch_time_s is not None]
        # Quarantine before deregister: entry count is observable only while
        # the executor's map still exists.
        self.faults.index_entries_quarantined += len(self.index.cached_at(name))
        quarantine = getattr(self.index, "quarantine_executor", None)
        if quarantine is not None:
            self.faults.bus_ops_purged += quarantine(name)
        self.dispatcher.deregister_executor(name)   # idempotent second drop
        if self.engine is not None:
            self.engine.fail_replica(name, now)
        self.stores.pop(name, None)
        self._idle_since.pop(name, None)
        if self._stop is not None:
            self._stop(name)
        for r in orphans:
            self.faults.requests_requeued += 1
            if self._slo is not None:
                self._slo.record_failure(now)   # availability burn
            # Reset to pre-dispatch state; the hit/miss work done on the
            # dead replica is lost and will be re-done (and re-counted)
            # wherever the request lands next.
            r.replica = None
            r.dispatch_time_s = None
            r.hits = 0
            r.misses = 0
            r.sources = {}
            r.restore_cost_s = 0.0
            self.dispatcher.submit(r)
        if self._trace is not None:
            self._trace.record(-1, name, "failure", now, now, name, "",
                               (len(orphans),))
        if self.drp is not None:
            self.drp.registered = max(0, self.drp.registered - 1)
            req = self.drp.request(1, now)     # 1:1 capacity back-fill
            if req is not None:
                self._pending_provisions.append(req)
                self.faults.backfills_requested += 1
        return orphans

    # ------------------------------------------------- liveness / heartbeats
    def record_heartbeat(self, name: str, step_time_s: Optional[float] = None,
                         now: Optional[float] = None) -> None:
        """Feed the liveness source; ``step_time_s`` drives EWMA straggler
        detection (a straggling replica stops winning cache-affinity ties)."""
        if self.monitor is not None:
            self.monitor.heartbeat(
                name, step_time_s,
                time.monotonic() if now is None else now)

    def check_liveness(self, now: Optional[float] = None) -> List[str]:
        """Crash replicas whose heartbeat lapsed; refresh straggler
        penalties.  Returns the names failed this call."""
        if self.monitor is None:
            return []
        now = time.monotonic() if now is None else now
        lost = [n for n in self.monitor.check(now) if n in self.stores]
        for name in lost:
            self.faults.heartbeat_losses += 1
            self.fail_replica(name, now)
        strag = {n: 1.0 for n in self.monitor.stragglers()
                 if n in self.stores}
        if strag != self.dispatcher.penalties:
            self.dispatcher.set_penalties(strag)
        self.faults.straggler_penalties = len(strag)
        return lost

    # ------------------------------------------------- corruption / brown-out
    def _note_corruption(self, replica: str, obj: str) -> None:
        """Payload backend detected a poisoned spill chunk (sha256 mismatch)
        while reading ``obj``.  Recovery is deferred to the next tick: drop
        the copy, quarantine its index entry, re-fetch from a clean source."""
        self.faults.payload_corruptions_recovered += 1
        self._corrupt_refetch.append((replica, obj))

    def _drain_corrupt_refetch(self, now: float) -> None:
        pending, self._corrupt_refetch = self._corrupt_refetch, []
        for replica, obj in pending:
            store = self.stores.get(replica)
            if store is None:
                continue                    # replica died meanwhile
            if obj in store:
                store.drop(obj)             # withdraws the index entry too
            if self.engine is not None:
                self.engine.fetch(obj, self.object_size_fn(obj), replica,
                                  now, allow_queue=True)
                self.faults.refetches_issued += 1

    def _browned_out(self, now: float) -> bool:
        """Failure-storm brown-out: when the availability SLO's fast burn
        rate fires, shed speculative traffic (prefetch warms, scale-up
        warm-starts) so recovery bandwidth goes to demand fetches."""
        if self._slo is None:
            return False
        tracker = self._slo.trackers.get("availability")
        if tracker is None:
            return False
        fast, _slow = tracker.burn_rates(now)
        active = fast >= tracker.spec.fire_burn
        self.faults.brownout_active = 1 if active else 0
        return active

    def replicas(self) -> List[str]:
        return list(self.stores)

    # --------------------------------------------------------------- submit
    def enqueue(self, request: RoutedRequest,
                now: Optional[float] = None) -> AdmissionVerdict:
        """Queue a request without running the drain — the batch-drain entry
        point: callers enqueue a burst, then ``tick()`` once so the whole
        burst is decided in a single window scan.

        Returns the admission verdict (the backpressure contract).  With no
        admission controller attached the verdict is ``ACCEPTED``
        unconditionally and the path is bit-identical to the pre-admission
        router.  ``REJECTED`` requests are refused at the edge — counted on
        the tenant's account and traced as a ``shed`` span, never silently
        dropped."""
        now = time.monotonic() if now is None else now
        if request.submit_time_s == 0.0:
            request.submit_time_s = now
        verdict = AdmissionVerdict.ACCEPTED
        if self.admission is not None:
            verdict = self.admission.on_submit(request, now)
            if verdict is AdmissionVerdict.REJECTED:
                self._shed_span(request, now, "rejected")
                return verdict
        self._requests[request.request_id] = request
        if verdict is AdmissionVerdict.ACCEPTED:
            self.dispatcher.submit(request)
        # DEGRADED: admitted into the controller's bounded tenant queue;
        # tick()'s admission pump releases it by credit share (or sheds it).
        if self.drp is not None:
            depth = self.dispatcher.queue_length()
            if self.admission is not None:
                depth += self.admission.queue_depth()
            req = self.drp.on_queue_change(now, depth)
            if req is not None:
                self._pending_provisions.append(req)
        return verdict

    def submit(self, request: RoutedRequest, now: Optional[float] = None) -> List[Assignment]:
        """Enqueue a request; returns any assignments routable right away."""
        now = time.monotonic() if now is None else now
        self.enqueue(request, now)
        return self.tick(now)

    def queue_length(self) -> int:
        return self.dispatcher.queue_length()

    def pending_admission(self) -> int:
        """Requests held under backpressure in tenant queues (0 without an
        admission controller — or whenever it is not overloaded)."""
        return self.admission.queue_depth() if self.admission is not None else 0

    # ----------------------------------------------------------- main pump
    def tick(self, now: Optional[float] = None) -> List[Assignment]:
        """Drive elasticity + phase-1 routing; returns new assignments."""
        now = time.monotonic() if now is None else now
        if self.engine is not None:
            self.engine.drain(now)      # release bandwidth of landed copies
        if self._corrupt_refetch:
            self._drain_corrupt_refetch(now)
        self._complete_provisions(now)
        if self.admission is not None:
            self._admission_pump(now)
        self._maybe_release(now)
        out = self._drain_notify(now)
        if self._perf is not None:
            # Pool-utilization sample for the live resource integral
            # (perf.resource_hours / perf.utilization), taken *after* the
            # drain so the burst just assigned counts: non-free replicas
            # (BUSY or PENDING-notified) are in use.
            n = self.dispatcher.registered()
            self._perf.on_sample(now, float(n),
                                 float(n - self.dispatcher.free_count()))
        return out

    def _shed_span(self, request: RoutedRequest, now: float,
                   reason: str) -> None:
        """Trace a shed/rejected request: wall time from submit to the shed
        decision, so the critical-path analyzer can attribute rejected-vs-
        served time.  Request-attributed (never sampled out)."""
        if self._trace is not None:
            t0 = request.submit_time_s or now
            self._trace.record(request.request_id, "shed", "shed",
                               t0, now, "", "",
                               (request.tenant or "default", reason))

    def _admission_pump(self, now: float) -> None:
        """Overload control loop, once per tick: adapt (dead-band credit
        controller), shed its victims, release queued work into the
        dispatcher by credit share, refresh tenant dispatch-tie weights."""
        adm = self.admission
        capacity = max(1, len(self.stores)) * max(1, self.pickup_batch)
        victims = adm.adapt(now, queued=self.dispatcher.queue_length(),
                            capacity=capacity)
        for r in victims:
            self._requests.pop(r.request_id, None)
            self._shed_span(r, now, "shed")
        if adm.queue_depth() > 0:
            if adm.overloaded:
                # keep the dispatcher fed to ~2x pool headroom; the rest
                # waits under backpressure in the tenant queues
                budget = max(0, 2 * capacity - self.dispatcher.queue_length())
            else:
                budget = adm.queue_depth()   # overload cleared: drain fully
            for r in adm.release(now, budget):
                self.dispatcher.submit(r)
        # Tenant-weighted pick-item ties engage only while overloaded (and
        # clear after), so a controller that never saw overload leaves the
        # dispatch sequence bit-identical to admission=None.
        if adm.overloaded and len(adm.tenants) > 1:
            weights = {n: st.share for n, st in adm.tenants.items()}
            if weights != self.dispatcher.tenant_weights:
                self.dispatcher.set_tenant_weights(weights)
        elif self.dispatcher.tenant_weights:
            self.dispatcher.set_tenant_weights({})

    def _drain_notify(self, now: float) -> List[Assignment]:
        if self.batch_drain:
            return self._drain_batched(now)
        out: List[Assignment] = []
        while True:
            pair = self.dispatcher.notify()
            if pair is None:
                return out
            replica, request = pair
            out.append(self._start(replica, [request], now))

    def _drain_batched(self, now: float) -> List[Assignment]:
        """Single-scan batched drain (the serving batch plane).

        ``notify_batch`` decides every assignable (replica, request) pair
        from one window scan over a frozen presence snapshot — nothing
        mutates dispatcher or index state between the emulated per-decision
        calls, which is exactly the precondition the vectorized engine's
        batched drain documents.  The batch is then *executed*: hits are
        accounted with tier promotions deferred into each store's delta log,
        misses are collected and admitted through one batched transfer
        resolution, and the promotion delta is applied once at the end.  The
        outer loop re-scans after applying (mirroring the looped path's
        terminal failed ``notify()``), so anything the batch's effects made
        assignable still goes out this tick.
        """
        out: List[Assignment] = []
        while True:
            pairs = self.dispatcher.notify_batch()
            if not pairs:
                return out
            for store in self.stores.values():
                store.tiers.defer_promotions()
            try:
                sink: List[Tuple] = []
                for replica, request in pairs:
                    out.append(self._start(replica, [request], now,
                                           miss_sink=sink))
                self._replay_batch(pairs, sink, now)
                trace = self._trace
                if trace is not None:
                    # Dispatch spans are finalized *after* the replay so the
                    # hit/miss attribution reflects stale-snapshot
                    # conversions — identical to what the looped path
                    # records at decision time (parity-asserted).
                    for replica, request in pairs:
                        srcs = request.sources
                        # Insertion-ordered; parity_digest canonicalizes
                        # (sorting here would tax every request to make a
                        # snapshot-time comparison cheaper).
                        trace.record(
                            request.request_id, "dispatch", "dispatch",
                            now, now, replica, "request",
                            (request.hits, request.misses,
                             tuple(srcs.items()) if srcs else ()))
                    # Structural: the whole wave was one window scan.
                    trace.record(-1, "drain", "drain", now, now,
                                 detail=(len(pairs),))
            finally:
                applied = 0
                for store in self.stores.values():
                    applied += store.tiers.apply_promotions()
                if applied and self._trace is not None:
                    # Structural: the coalesced tier-promotion replay.
                    # Drains that promoted nothing record nothing — an
                    # empty replay is not an event.
                    self._trace.record(-1, "promote_replay", "promote",
                                       now, now, detail=(applied,))

    def _replay_batch(self, pairs: List[Tuple[str, RoutedRequest]],
                      sink: List[Tuple], now: float) -> None:
        """Execute a drained batch's store mutations in looped order.

        Each assignment's entries replay in per-request object order —
        promotion here, admission there — so cache recency (and therefore
        every future eviction victim) evolves exactly as the looped
        per-decision path's would.  Source resolution happens *at the
        replay position* through one shared batch resolver (one drain,
        candidate sorts amortized), so an admission earlier in the batch
        that evicted a peer's copy is seen exactly as sequential fetches
        would see it.  A "hit" entry whose object an earlier admission's
        eviction cascade dropped off the stack is converted back to the
        miss the looped path would have taken (its recorded tier/cost
        accounting is reversed exactly).  first-available records nothing
        in the sink, so its replay is a no-op by construction.
        """
        resolve = None
        by_replica: Dict[str, List[Tuple]] = {}
        for replica, obj, kind, tier, amount in sink:
            by_replica.setdefault(replica, []).append((obj, kind, tier, amount))

        def admit_miss(request: RoutedRequest, store: ReplicaStore,
                       replica: str, obj: str, size: float) -> None:
            nonlocal resolve
            if resolve is None:
                resolve = self.engine.batch_resolver(now)
            tr = resolve(obj, size, replica, admit=False)
            request.sources[obj] = tr.source
            cost = tr.remaining_s(now)
            request.restore_cost_s += cost
            self.stats.restore_time_s += cost
            if self._trace is not None:
                self._trace.record(request.request_id, obj, "transfer",
                                   now, now + cost, replica, "dispatch",
                                   (tr.source,))
            store.admit(obj, tr.size_bytes)
            if obj not in store.tiers:
                # Pass-through (fits no tier): the scan's admission overlay
                # assumed this copy would exist — count the staleness.
                self.stats.stale_snapshot_drops += 1

        for replica, request in pairs:
            store = self.stores[replica]
            for obj, kind, tier, amount in by_replica.get(replica, ()):
                if kind == "hit":
                    if obj in store.tiers:
                        store.tiers.apply_promotion(obj)
                        continue
                    # Cascade-dropped before its replay position: reverse
                    # the hit accounting and take the looped path's miss.
                    self.stats.stale_snapshot_drops += 1
                    request.hits -= 1
                    self.stats.object_hits -= 1
                    self.stats.hits_by_tier[tier] -= 1
                    if self.stats.hits_by_tier[tier] == 0:
                        del self.stats.hits_by_tier[tier]   # as looped never
                        #                                     created the key
                    request.restore_cost_s -= amount
                    self.stats.restore_time_s -= amount
                    request.misses += 1
                    self.stats.object_misses += 1
                    admit_miss(request, store, replica, obj,
                               self.object_size_fn(obj))
                elif kind == "miss":        # counted at decision time
                    admit_miss(request, store, replica, obj, amount)
                else:                       # dupmiss: second occurrence of a
                    # just-admitted object — a top-tier hit paying the
                    # transfer's remaining time (unless a cascade dropped
                    # it again in between, then it is a fresh miss).
                    found = store.access(obj)
                    if found is None:
                        self.stats.stale_snapshot_drops += 1
                        request.hits -= 1
                        self.stats.object_hits -= 1
                        request.misses += 1
                        self.stats.object_misses += 1
                        admit_miss(request, store, replica, obj, amount)
                        continue
                    self.stats.hits_by_tier[found] = \
                        self.stats.hits_by_tier.get(found, 0) + 1
                    request.sources[obj] = found
                    cost = self.engine.remaining_s(replica, obj, now)
                    request.restore_cost_s += cost
                    self.stats.restore_time_s += cost
                    if self._trace is not None and found != store.top_tier \
                            and cost > 0.0:
                        # Mirror of the looped path's lower-tier-hit span.
                        self._trace.record(request.request_id, obj,
                                           "promote", now, now + cost,
                                           replica, "dispatch", (found,))
        # Prefetch warms run after the replay (the looped path warms at the
        # end of each _start, i.e. after that request's own admissions) —
        # per-store mutation order is preserved.  In batch mode the warm
        # targets the post-batch queue: the whole burst was already
        # decided, so speculation goes to work actually still waiting.
        if self.prefetcher is not None:
            if pairs and self._browned_out(now) \
                    and self.dispatcher.queue_length() > 0:
                self.faults.brownout_sheds += 1
            else:
                for replica, _request in pairs:
                    if self.dispatcher.queue_length() == 0:
                        break
                    for item in self.dispatcher.peek(self.prefetch_depth):
                        self.prefetcher.warm(
                            replica, self.dispatcher.objects_of(item), now)

    def _start(self, replica: str, requests: List[RoutedRequest], now: float,
               miss_sink: Optional[List[Tuple]] = None,
               ) -> Assignment:
        """Start ``requests`` on ``replica`` (hit/miss accounting + data
        movement).  With ``miss_sink`` (the batched drain), every cached-path
        object position appends a replay entry ``(replica, obj, kind, tier,
        amount)`` — kind "hit" (tier found, cost charged), "miss" (amount =
        size), or "dupmiss" (same object's second occurrence riding the
        first's admission) — and the store-mutating half (admissions, source
        resolution, promotion application) is deferred to the caller's
        ordered replay."""
        self.dispatcher.set_state(replica, ExecutorState.BUSY)
        store = self.stores[replica]
        use_cache = self.dispatcher.provides_location_info()
        trace = self._trace
        for request in requests:
            request.replica = replica
            request.dispatch_time_s = now
            self.stats.routed += 1
            if self.assignment_log is not None:
                self.assignment_log.append(f"{request.request_id}->{replica}")
            sunk: set = set()       # objects this request already miss-sank
            for obj in request.objects:
                # Access-heat feed: the warm-start plane ranks clone
                # candidates by these per-object counters (decayed toward
                # the *current* hot set when the index has a heat half-life).
                self.index.note_access(obj, now=now)
                if not use_cache:
                    # first-available: every access replays from persistent
                    # storage and nothing is kept.
                    request.misses += 1
                    self.stats.object_misses += 1
                    self.stats.bytes_from_persistent += self.object_size_fn(obj)
                    if trace is not None:
                        trace.record(request.request_id, obj, "transfer",
                                     now, now, replica, "dispatch",
                                     ("persistent",))
                    continue
                # Intent logged by a *previous* access of this request (the
                # epoch holds at most this one request's intents): checked
                # before access(), which may log one for obj itself.
                pre_intent = miss_sink is not None and store.tiers.has_intent(obj)
                tier = store.access(obj)
                if tier is not None:
                    if pre_intent and tier != store.top_tier:
                        # Second hit on an object whose first hit (earlier
                        # in this request) logged a promote intent: the
                        # looped path already relocated it, so this access
                        # would have found it at the top tier for free.
                        tier = store.top_tier
                    request.hits += 1
                    self.stats.object_hits += 1
                    self.stats.hits_by_tier[tier] = \
                        self.stats.hits_by_tier.get(tier, 0) + 1
                    request.sources[obj] = tier
                    cost = self._hit_cost(store, replica, obj, tier, now)
                    request.restore_cost_s += cost
                    if trace is not None and tier != store.top_tier and cost > 0.0:
                        # Lower-tier hit: the swap-in toward HBM is the
                        # analyzer's "promote" segment (request-attributed,
                        # never sampled out; identical in both drain modes
                        # since cost is computed pre-replay).
                        trace.record(request.request_id, obj, "promote",
                                     now, now + cost, replica, "dispatch",
                                     (tier,))
                    if miss_sink is not None and self.engine is not None:
                        # flat mode (no engine) admits inline, so its hits
                        # can never be invalidated by a deferred admission
                        # — only the tiered path records hit entries.
                        miss_sink.append((replica, obj, "hit", tier, cost))
                elif miss_sink is not None and obj in sunk:
                    # Batched drain, same object twice in one request: the
                    # looped path would hit the copy its first miss just
                    # admitted — count the hit now; tier/source/cost are
                    # filled by the replay once the admission lands.
                    request.hits += 1
                    self.stats.object_hits += 1
                    size = self.object_size_fn(obj)
                    miss_sink.append((replica, obj, "dupmiss", None, size))
                else:
                    # miss: diffuse the object in — cheapest of peer NIC vs
                    # persistent store (tiered mode), or PR-1's zero-cost
                    # admit (flat mode).
                    request.misses += 1
                    self.stats.object_misses += 1
                    size = self.object_size_fn(obj)
                    if self.engine is not None and miss_sink is not None:
                        # batched drain: defer to the one-pass union
                        # resolution + ordered replay in _drain_batched
                        # (sources/cost filled after every decision of the
                        # batch is made).
                        sunk.add(obj)
                        miss_sink.append((replica, obj, "miss", None, size))
                    elif self.engine is not None:
                        tr = self.engine.fetch(obj, size, replica, now)
                        request.sources[obj] = tr.source
                        cost = tr.remaining_s(now)
                        request.restore_cost_s += cost
                        if trace is not None:
                            trace.record(request.request_id, obj, "transfer",
                                         now, now + cost, replica,
                                         "dispatch", (tr.source,))
                    else:
                        request.sources[obj] = "persistent"
                        self.stats.bytes_from_persistent += size
                        store.admit(obj, size)
                        if trace is not None:
                            trace.record(request.request_id, obj, "transfer",
                                         now, now, replica, "dispatch",
                                         ("persistent",))
            self.stats.restore_time_s += request.restore_cost_s
            if trace is not None and miss_sink is None:
                # Looped/pickup path: the decision is final here.  The
                # batched drain records its dispatch spans after the replay
                # instead, once stale-snapshot conversions are resolved —
                # both modes carry identical attribution (parity-asserted).
                srcs = request.sources
                trace.record(request.request_id, "dispatch", "dispatch",
                             now, now, replica, "request",
                             (request.hits, request.misses,
                              tuple(srcs.items()) if srcs else ()))
        # Warm this replica for the next queued work while it computes: the
        # transfer overlaps the batch it was just assigned (prefetch plane).
        # In the batched drain (miss_sink set) the warm is deferred to after
        # the batch replay so speculative admissions cannot interleave ahead
        # of the batch's own deferred store mutations.
        if self.prefetcher is not None and miss_sink is None \
                and self.dispatcher.queue_length() > 0:
            if self._browned_out(now):
                self.faults.brownout_sheds += 1
            else:
                for item in self.dispatcher.peek(self.prefetch_depth):
                    self.prefetcher.warm(replica,
                                         self.dispatcher.objects_of(item), now)
        return Assignment(replica, requests)

    def _hit_cost(self, store: ReplicaStore, replica: str, obj: str,
                  tier: str, now: float) -> float:
        """Swap-in cost of a hit: 0 at the top tier; lower tiers pay a read
        at the tier's bandwidth; an object whose transfer is still in flight
        (admitted early by the engine) pays the remaining transfer time."""
        if self.prefetcher is not None:
            self.prefetcher.on_access(replica, obj, now)
        pending = self.engine.remaining_s(replica, obj, now) if self.engine else 0.0
        if tier == store.top_tier:
            return pending
        bw = store.tiers.tier_bw(tier)
        swap = self.object_size_fn(obj) / max(bw.available(), 1e-9)
        return max(pending, swap)

    def warm_start(self, name: str, now: Optional[float] = None) -> WarmStartReport:
        """Bulk-clone the hottest index objects into replica ``name``.

        Runs automatically on DRP scale-up when ``warmstart_objects > 0``;
        callable directly for manually added replicas.  Clones ride the
        transfer engine's *speculative* priority class, so live demand
        fetches preempt them instead of queueing behind the warm-up."""
        now = time.monotonic() if now is None else now
        report = clone_hottest(
            self.index,
            self.stores[name].tiers,
            name,
            self.object_size_fn,
            now,
            max_objects=self.warmstart_objects,
            engine=self.engine,
            admit_tier=self.warmstart_admit_tier,
            hbm_heat_threshold=self.warmstart_hbm_heat,
        )
        self.warmstart.merge(report)
        return report

    def persistent_bytes_read(self) -> float:
        """Total bytes pulled from the persistent store (both modes)."""
        if self.engine is not None:
            return self.engine.stats.bytes_from_persistent + self.stats.bytes_from_persistent
        return self.stats.bytes_from_persistent

    # ------------------------------------------------------------- complete
    def _finish(self, request: RoutedRequest, now: float) -> Optional[str]:
        """Completion bookkeeping; returns the freed replica (if still ours)."""
        if request.dispatch_time_s is None or request.finish_time_s is not None:
            # At-most-once: a crashed replica reporting a completion for a
            # request that was already requeued (dispatch_time_s reset by
            # fail_replica) — or a double complete() — must not double-count.
            # The requeued request completes wherever it was re-dispatched.
            self.faults.stale_completions_dropped += 1
            return None
        request.finish_time_s = now
        self._requests.pop(request.request_id, None)
        self.stats.completed += 1
        if request.response_time_s is not None:
            self.stats.latencies_s.append(request.response_time_s)
            if self._slo is not None:
                self._slo.on_complete(now, request.response_time_s,
                                      request.hits, request.misses)
            if self.admission is not None:
                self.admission.on_complete(request.tenant or "default", now,
                                           request.response_time_s,
                                           request.hits, request.misses)
        replica = request.replica
        if self._trace is not None:
            # Root span: submit -> finish, closing the request's causal chain.
            self._trace.record(request.request_id, "request", "request",
                               request.submit_time_s, now, replica or "",
                               "", (request.hits, request.misses))
        if self._perf is not None and request.dispatch_time_s is not None:
            self._perf.on_complete(now, now - request.dispatch_time_s,
                                   request.hits, request.misses)
        if replica in self.stores:
            self.dispatcher.set_state(replica, ExecutorState.FREE)
            self._idle_since[replica] = now
            return replica
        return None

    def _pickup(self, replica: str, now: float) -> Optional[Assignment]:
        """Falkon pickup: a freed replica asks for window-scored work."""
        if replica in self.stores and self.dispatcher.queue_length() > 0 \
                and self.dispatcher.executor_state(replica) == ExecutorState.FREE:
            self.dispatcher.set_state(replica, ExecutorState.PENDING)
            picked = self.dispatcher.pick_items(replica, m=self.pickup_batch)
            if picked:
                return self._start(replica, picked, now)
        return None

    def complete(self, request: RoutedRequest, now: Optional[float] = None) -> List[Assignment]:
        """Replica finished a request: free it and run the pickup path."""
        now = time.monotonic() if now is None else now
        replica = self._finish(request, now)
        assignments = self.tick(now)
        if replica is not None:
            picked = self._pickup(replica, now)
            if picked is not None:
                assignments.append(picked)
        return assignments

    def complete_batch(self, requests: Sequence[RoutedRequest],
                       now: Optional[float] = None) -> List[Assignment]:
        """Batched completion: free a whole wave of finished replicas, then
        run *one* drain and one pickup pass.

        The per-request ``complete`` runs a full phase-1 drain per
        completion — at serving rates that is the dominant scheduling cost
        (N completions = N window scans).  Completing the wave together
        amortizes it to a single drain (single-scan with ``batch_drain``),
        then offers phase-2 pickups to the replicas phase 1 left free, in
        completion order.  Decisions match per-request completion whenever
        the drain's decisions are insensitive to the completion
        interleaving (the batch-plane contract; bench_serve_batch asserts
        it on its seeded streams).
        """
        now = time.monotonic() if now is None else now
        freed = [r for r in (self._finish(req, now) for req in requests)
                 if r is not None]
        assignments = self.tick(now)
        for replica in freed:
            picked = self._pickup(replica, now)
            if picked is not None:
                assignments.append(picked)
        return assignments

    # ----------------------------------------------------------- elasticity
    def _complete_provisions(self, now: float) -> None:
        if self.drp is None:
            return
        due = [r for r in self._pending_provisions if r.ready_time_s <= now]
        for req in due:
            self._pending_provisions.remove(req)
            self.drp.complete(req)
            for _ in range(req.nodes):
                name = self.add_replica(now=now)
                self.stats.scale_ups += 1
                if self._spawn is not None:
                    self._spawn(name)
                if self.warmstart_objects > 0:
                    # Scale-up happened because load is high — exactly when a
                    # cold replica's miss streak hurts most.  Clone the
                    # hottest peer-held objects in before it takes work —
                    # unless a failure storm browned us out, in which case
                    # the bandwidth belongs to demand recovery.
                    if self._browned_out(now):
                        self.faults.brownout_sheds += 1
                    else:
                        self.warm_start(name, now)

    def _maybe_release(self, now: float) -> None:
        if self.drp is None or self.dispatcher.queue_length() > 0:
            return
        if self.admission is not None:
            # Admitted (non-shed) demand still waiting under backpressure
            # keeps its capacity: a valley right after a shed episode must
            # not over-shrink the pool.  Feed the DRP's demand floor and
            # skip release entirely while tenant queues are backlogged.
            pending = self.admission.queue_depth()
            self.drp.demand_floor = math.ceil(
                pending / max(1.0, self.drp.tasks_per_node_target))
            if pending > 0:
                return
        for name in list(self.stores):
            if self.dispatcher.executor_state(name) != ExecutorState.FREE:
                continue
            if len(self.stores) <= self.drp.min_nodes:
                return
            idle_since = self._idle_since.get(name)
            if idle_since is None:
                self._idle_since[name] = now   # first sighting: clock starts
                continue
            if self.drp.should_release(idle_since, now):
                self.drp.release(1)
                self.stats.scale_downs += 1
                if self._stop is not None:
                    self._stop(name)
                self.remove_replica(name)
