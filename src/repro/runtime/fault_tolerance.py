"""Fault tolerance: heartbeats, failure injection, replay, elastic restart.

The executor-state machine is shared with the DES (``core``); here it is
driven by wall-clock heartbeats.  The recovery ladder mirrors the paper's
replay policy upward:

  task level    — timed-out / failed tasks re-dispatch (replay policy);
  worker level  — missed heartbeats mark the worker LOST, its cache entries
                  drop from the index, the DRP back-fills capacity;
  job level     — the train loop restarts from the latest committed
                  checkpoint onto the surviving mesh (elastic restore).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from ..core.provisioner import DynamicResourceProvisioner
from ..core.scheduler import DataAwareScheduler


@dataclass
class WorkerHealth:
    name: str
    last_heartbeat: float
    step_times: List[float] = field(default_factory=list)
    lost: bool = False

    def ewma_step_time(self, alpha: float = 0.3) -> float:
        if not self.step_times:
            return 0.0
        v = self.step_times[0]
        for t in self.step_times[1:]:
            v = alpha * t + (1 - alpha) * v
        return v


class HeartbeatMonitor:
    """Tracks worker liveness + straggler status from reported step times."""

    def __init__(self, timeout_s: float = 5.0, straggler_factor: float = 2.0):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.workers: Dict[str, WorkerHealth] = {}
        self._lock = threading.Lock()

    def register(self, name: str, now: Optional[float] = None) -> None:
        with self._lock:
            self.workers[name] = WorkerHealth(name, now if now is not None else time.time())

    def heartbeat(self, name: str, step_time_s: Optional[float] = None,
                  now: Optional[float] = None) -> None:
        with self._lock:
            w = self.workers.get(name)
            if w is None:
                return
            w.last_heartbeat = now if now is not None else time.time()
            if step_time_s is not None:
                w.step_times.append(step_time_s)
                if len(w.step_times) > 64:
                    w.step_times.pop(0)

    def check(self, now: Optional[float] = None) -> List[str]:
        """Returns newly-lost worker names (missed heartbeat)."""
        now = now if now is not None else time.time()
        lost = []
        with self._lock:
            for w in self.workers.values():
                if not w.lost and now - w.last_heartbeat > self.timeout_s:
                    w.lost = True
                    lost.append(w.name)
        return lost

    def stragglers(self) -> List[str]:
        """Workers whose EWMA step time exceeds factor x median."""
        with self._lock:
            times = {n: w.ewma_step_time() for n, w in self.workers.items()
                     if not w.lost and w.step_times}
        if len(times) < 2:
            return []
        med = sorted(times.values())[len(times) // 2]
        if med <= 0:
            return []
        return [n for n, t in times.items() if t > self.straggler_factor * med]

    def forget(self, name: str) -> None:
        """Worker left the fleet (crash or scale-down): stop tracking it so
        its stale EWMA cannot skew the straggler median and a re-registered
        namesake starts with a clean window."""
        with self._lock:
            self.workers.pop(name, None)

    def alive(self) -> List[str]:
        with self._lock:
            return [n for n, w in self.workers.items() if not w.lost]


class FailureInjector:
    """Deterministic failure schedule for tests/examples."""

    def __init__(self, schedule: Dict[int, List[str]]):
        self.schedule = dict(schedule)  # step -> worker names to kill

    def maybe_fail(self, step: int) -> List[str]:
        return self.schedule.pop(step, [])


@dataclass
class RecoveryActions:
    lost_workers: List[str]
    redispatch_tasks: int
    restart_from_step: Optional[int]
    provision_requested: int


def recover(
    monitor: HeartbeatMonitor,
    scheduler: Optional[DataAwareScheduler],
    provisioner: Optional[DynamicResourceProvisioner],
    *,
    latest_ckpt_step: Optional[int],
    lost: List[str],
    now: float = 0.0,
) -> RecoveryActions:
    """The worker-level recovery ladder (pure function for testability)."""
    redispatched = 0
    requested = 0
    for name in lost:
        if scheduler is not None:
            scheduler.deregister_executor(name)
    if provisioner is not None and lost:
        provisioner.registered = max(0, provisioner.registered - len(lost))
        req = provisioner.request(len(lost), now)  # 1:1 back-fill
        requested = req.nodes if req else 0
    return RecoveryActions(
        lost_workers=lost,
        redispatch_tasks=redispatched,
        restart_from_step=latest_ckpt_step if lost else None,
        provision_requested=requested,
    )
