"""Bench regression sentinel: flag perf drops the BENCH history can prove.

Every benchmark appends a timestamped entry to its ``BENCH_*.json``
``history`` (see ``benchmarks/bench_util.append_history``).  This module
turns that trajectory into a CI gate: for each **declared** metric it
builds a robust baseline — median and MAD (median absolute deviation) over
the last ``last_k`` *config-matched* prior entries — and flags the latest
run only when it falls beyond a noise-scaled threshold on the metric's bad
side:

    threshold = max(abs_floor, rel_floor * |median|, mad_mult * 1.4826 * MAD)
    regression (higher-is-better):  latest < median - threshold
    regression (lower-is-better):   latest > median + threshold

Design points the real histories forced:

  * **Config matching.** One file's history mixes workload sizes (e.g.
    ``requests=300`` vs ``3000`` runs of the serve bench) whose absolute
    rates differ by design; baselines compare like with like by matching
    the latest entry's ``config`` dict exactly, falling back (with a note)
    to all entries carrying the metric only when matches are too few.
  * **Robustness over recency.** Median/MAD ignores a single outlier run
    (a noisy CI machine) where mean/stddev would chase it; the relative
    floor keeps near-zero-MAD histories (identical repeated runs) from
    flagging on measurement jitter.
  * **One-sided.** Improvements never flag, however large.
  * **Schema tolerance.** Entries predating the ``schema`` stamp (or
    carrying ``migrated: true``) are plain dicts with metric keys — they
    participate normally; entries *missing* a metric are skipped, and a
    document whose ``schema`` is newer than this module understands is
    skipped entirely with a note (never a false alarm on format drift).

``main()`` scans the given BENCH files, writes a markdown report, and
returns a process exit code: 0 quiet, 1 regressions found — wired into CI
via ``python -m benchmarks.run --check-regressions``.

Stdlib-only; no repro imports beyond the sibling registry (schema const).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .registry import SCHEMA_VERSION

__all__ = ["DECLARED_METRICS", "MetricSpec", "RegressionReport",
           "check_file", "main", "render_markdown"]


@dataclass(frozen=True)
class MetricSpec:
    """One watched metric inside a BENCH document's history entries.

    ``key`` is a dotted path into the entry (``measured_gbps.hbm->dram``
    reads the nested per-edge dict the payload bench writes).
    """

    key: str
    higher_is_better: bool = True
    rel_floor: float = 0.10      # min relative drop worth flagging
    abs_floor: float = 0.0       # min absolute drop (near-zero medians make
                                 # the relative floor meaningless)
    mad_mult: float = 3.0        # noise scale: 3 robust sigmas
    min_history: int = 3         # baseline entries required to judge
    last_k: int = 8              # baseline window (most recent prior runs)


#: The watched surface, by BENCH file basename.  Adding a metric here is
#: the whole act of putting it under sentinel protection.
DECLARED_METRICS: Dict[str, Tuple[MetricSpec, ...]] = {
    "BENCH_serve.json": (
        MetricSpec("batched_rps"),
        # The looped reference path runs at a smaller request count, so its
        # rate is dominated by machine weather; the batched headline above
        # keeps the tight floor.
        MetricSpec("looped_rps", rel_floor=0.30),
        MetricSpec("measured_swapin_gbps"),
        # Observability tax: lower is better, and the healthy value sits
        # near zero (sometimes below — measurement jitter), so only an
        # absolute drift matters; the bench's significance-tested 0.95x
        # gate is the hard per-run enforcement.
        MetricSpec("obs_overhead_pct", higher_is_better=False,
                   rel_floor=0.50, abs_floor=15.0),
    ),
    "BENCH_dispatch.json": (
        MetricSpec("vectorized_decisions_per_s"),
        MetricSpec("reference_decisions_per_s"),
    ),
    "BENCH_payload.json": (
        MetricSpec("measured_gbps.hbm->dram"),
        MetricSpec("measured_gbps.disk->hbm"),
    ),
    "BENCH_admission.json": (
        # Wall-clock throughput of the overload storm and the idle-parity
        # pump; the fairness invariants themselves are hard per-run raises
        # in the bench, so only the perf trajectory needs the sentinel.
        MetricSpec("overload.rps", rel_floor=0.30),
        MetricSpec("idle_parity.rps", rel_floor=0.30),
    ),
}


def _lookup(entry: Dict[str, Any], dotted: str) -> Optional[float]:
    node: Any = entry
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    if isinstance(node, bool) or not isinstance(node, (int, float)):
        return None
    return float(node)


def _median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def _mad_sigma(xs: Sequence[float], med: float) -> float:
    """Robust sigma estimate: 1.4826 * median(|x - med|)."""
    if not xs:
        return 0.0
    return 1.4826 * _median([abs(x - med) for x in xs])


@dataclass
class Finding:
    """Judgement for one (file, metric) pair."""

    file: str
    metric: str
    status: str                  # "ok" | "regression" | "skipped"
    latest: Optional[float] = None
    baseline: Optional[float] = None
    threshold: Optional[float] = None
    n_baseline: int = 0
    note: str = ""

    @property
    def delta_pct(self) -> Optional[float]:
        if self.latest is None or not self.baseline:
            return None
        return 100.0 * (self.latest - self.baseline) / abs(self.baseline)


@dataclass
class RegressionReport:
    findings: List[Finding]

    @property
    def regressions(self) -> List[Finding]:
        return [f for f in self.findings if f.status == "regression"]

    @property
    def exit_code(self) -> int:
        return 1 if self.regressions else 0


def check_file(path: str,
               specs: Optional[Sequence[MetricSpec]] = None) -> List[Finding]:
    """Judge every declared metric of one BENCH document."""
    base = os.path.basename(path)
    if specs is None:
        specs = DECLARED_METRICS.get(base, ())
    if not specs:
        return [Finding(base, "*", "skipped", note="no declared metrics")]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [Finding(base, "*", "skipped", note=f"unreadable: {e}")]
    schema = doc.get("schema", 0)
    if isinstance(schema, (int, float)) and schema > SCHEMA_VERSION:
        return [Finding(base, "*", "skipped",
                        note=f"schema {schema} newer than supported "
                             f"{SCHEMA_VERSION}")]
    history = doc.get("history") or []
    if not history:
        return [Finding(base, "*", "skipped", note="no history")]
    latest = history[-1]
    prior = history[:-1]

    out: List[Finding] = []
    for spec in specs:
        value = _lookup(latest, spec.key)
        if value is None:
            out.append(Finding(base, spec.key, "skipped",
                               note="metric absent from latest entry"))
            continue
        # Baseline pool: config-matched prior entries carrying the metric;
        # fall back to all carriers when matches are too few (noted, so a
        # quiet verdict on mixed configs is auditable).
        cfg = latest.get("config")
        matched = [e for e in prior
                   if e.get("config") == cfg and _lookup(e, spec.key) is not None]
        note = ""
        pool = matched
        if len(matched) < spec.min_history:
            pool = [e for e in prior if _lookup(e, spec.key) is not None]
            if len(pool) > len(matched):
                note = "config-mismatched baseline (few matching runs)"
        values = [_lookup(e, spec.key) for e in pool[-spec.last_k:]]
        if len(values) < spec.min_history:
            out.append(Finding(base, spec.key, "skipped", latest=value,
                               n_baseline=len(values),
                               note=f"history too short "
                                    f"({len(values)} < {spec.min_history})"))
            continue
        med = _median(values)
        threshold = max(spec.abs_floor, spec.rel_floor * abs(med),
                        spec.mad_mult * _mad_sigma(values, med))
        if spec.higher_is_better:
            bad = value < med - threshold
        else:
            bad = value > med + threshold
        out.append(Finding(
            base, spec.key, "regression" if bad else "ok",
            latest=value, baseline=med, threshold=threshold,
            n_baseline=len(values), note=note))
    return out


def render_markdown(report: RegressionReport) -> str:
    lines = ["# Bench regression sentinel", ""]
    regs = report.regressions
    if regs:
        lines.append(f"**{len(regs)} regression(s) flagged.**")
    else:
        lines.append("No regressions flagged.")
    lines += ["", "| file | metric | status | latest | baseline (median) "
              "| delta | note |", "|---|---|---|---:|---:|---:|---|"]

    def fmt(v: Optional[float]) -> str:
        return f"{v:.4g}" if v is not None else "-"

    order = {"regression": 0, "ok": 1, "skipped": 2}
    for f in sorted(report.findings,
                    key=lambda f: (order[f.status], f.file, f.metric)):
        d = f.delta_pct
        delta = f"{d:+.1f}%" if d is not None else "-"
        lines.append(f"| {f.file} | {f.metric} | {f.status} | "
                     f"{fmt(f.latest)} | {fmt(f.baseline)} | {delta} "
                     f"| {f.note} |")
    return "\n".join(lines) + "\n"


def check_paths(paths: Sequence[str]) -> RegressionReport:
    findings: List[Finding] = []
    for p in paths:
        findings.extend(check_file(p))
    return RegressionReport(findings)


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI: ``python -m repro.obs.regress [--report OUT.md] BENCH...``."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="BENCH_*.json files (default: declared set in cwd)")
    ap.add_argument("--report", default="",
                    help="write the markdown report here too")
    ns = ap.parse_args(argv)
    paths = list(ns.paths) or [p for p in DECLARED_METRICS
                               if os.path.exists(p)]
    report = check_paths(paths)
    md = render_markdown(report)
    print(md, end="")
    if ns.report:
        with open(ns.report, "w") as f:
            f.write(md)
    return report.exit_code


if __name__ == "__main__":
    raise SystemExit(main())
