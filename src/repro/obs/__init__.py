"""Unified observability plane: metrics registry + trace spans + live perf.

One ``Observability`` object per serving process, threaded through
``DiffusionServer(obs=...)`` / ``CacheAffinityRouter(obs=...)`` /
``Simulator(obs=...)``:

  * ``obs.registry`` — the metrics namespace.  Every ``*Stats`` island is
    adopted as a ``snapshot()`` source under its plane prefix
    (``router.hit_rate``, ``transfer.bytes.peer``, ``dispatch.decisions``,
    ``serve.prefix_hits`` …); nothing is copied or double-counted.
  * ``obs.trace``    — the per-request span ring (``obs.trace``), exportable
    as JSONL and Chrome-trace/Perfetto JSON.
  * ``obs.perf``     — the live reducer for the paper's evaluation metrics
    (``perf.performance_index``, ``perf.speedup``, per-interval throughput
    and utilization rows), name-shared with the DES projection in
    ``obs.perf.sim_perf_rows`` so sim-vs-live curves overlay.

**Overhead contract**: obs is opt-in and ``obs=None`` (the default
everywhere) is a no-op stub path — consumers hold ``trace = obs.trace if
obs else None`` and guard each hook with one ``is not None`` test, so the
disabled path allocates no span objects and performs no metric work
(asserted by ``tests/test_obs.py``); the enabled path must cost <= 5% of
``bench_serve_batch`` requests/sec (asserted as an ERROR row, measured
overhead recorded in ``BENCH_serve.json``).

``collect_all()`` is the one entry point that merges every adopted island;
``write_snapshot(dir)`` dumps ``metrics.json`` (flat metrics + per-interval
perf rows, schema-versioned) plus ``trace.jsonl`` and
``trace_chrome.json`` — the artifacts ``repro.launch.serve --metrics-dir``
emits and CI uploads next to the ``BENCH_*.json`` history.
"""

from __future__ import annotations

import json
import os
from datetime import datetime, timezone
from typing import Any, Dict, Optional

from .perf import PerfMeter, sim_perf_rows, sim_perf_summary
from .registry import (SCHEMA_VERSION, Counter, Gauge, MetricsRegistry,
                       WindowedHistogram, nearest_rank_index, stats_snapshot)
from .trace import PARITY_PHASES, TraceBuffer

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "Observability",
    "PARITY_PHASES",
    "PerfMeter",
    "SCHEMA_VERSION",
    "TraceBuffer",
    "WindowedHistogram",
    "nearest_rank_index",
    "sim_perf_rows",
    "sim_perf_summary",
    "stats_snapshot",
]


class Observability:
    """Registry + tracer + perf reducer, wired together."""

    def __init__(
        self,
        trace_maxlen: int = 65536,
        perf_interval_s: float = 1.0,
        baseline_service_s: Optional[float] = None,
    ):
        self.registry = MetricsRegistry()
        self.trace = TraceBuffer(maxlen=trace_maxlen)
        self.perf = PerfMeter(interval_s=perf_interval_s,
                              baseline_service_s=baseline_service_s)
        self.registry.register_source("perf", self.perf)
        self.registry.register_source("trace", self.trace)

    def collect_all(self) -> Dict[str, float]:
        """Every adopted island + instrument, one flat dotted namespace."""
        return self.registry.collect()

    def write_snapshot(self, out_dir: str, tag: str = "") -> Dict[str, str]:
        """Dump metrics + trace artifacts into ``out_dir``; returns paths."""
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        metrics_path = os.path.join(out_dir, f"metrics{suffix}.json")
        jsonl_path = os.path.join(out_dir, f"trace{suffix}.jsonl")
        chrome_path = os.path.join(out_dir, f"trace_chrome{suffix}.json")
        doc = {
            "schema_version": SCHEMA_VERSION,
            "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
            "metrics": self.collect_all(),
            "perf_intervals": self.perf.interval_rows(),
        }
        with open(metrics_path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        self.trace.to_jsonl(jsonl_path)
        self.trace.write_chrome_trace(chrome_path)
        return {"metrics": metrics_path, "trace_jsonl": jsonl_path,
                "trace_chrome": chrome_path}
