"""Unified observability plane: metrics, traces, perf, analysis, SLOs.

One ``Observability`` object per serving process, threaded through
``DiffusionServer(obs=...)`` / ``CacheAffinityRouter(obs=...)`` /
``Simulator(obs=...)``:

  * ``obs.registry`` — the metrics namespace.  Every ``*Stats`` island is
    adopted as a ``snapshot()`` source under its plane prefix
    (``router.hit_rate``, ``transfer.bytes.peer``, ``dispatch.decisions``,
    ``serve.prefix_hits`` …); nothing is copied or double-counted.
  * ``obs.trace``    — the per-request span ring (``obs.trace``), exportable
    as JSONL and Chrome-trace/Perfetto JSON.  ``trace_sample=N`` thins the
    batch-level structural spans 1-in-N; request-attributed spans are
    always recorded (parity and attribution are sampling-invariant).
  * ``obs.perf``     — the live reducer for the paper's evaluation metrics
    (``perf.performance_index``, ``perf.speedup``, per-interval throughput
    and utilization rows), name-shared with the DES projection in
    ``obs.perf.sim_perf_rows`` so sim-vs-live curves overlay.
  * ``obs.analyze``  — critical-path attribution over the trace ring:
    per-request wall time decomposed into non-overlapping segments (queue /
    dispatch / promote / transfer_peer / transfer_persistent / payload /
    service), surfaced as ``analyze.crit.*`` and a markdown blame report.
  * ``obs.slo``      — declarative SLOs (latency / hit-rate / availability)
    with error budgets and multi-window burn-rate alerts, surfaced as
    ``slo.*``; ``None`` when no specs were configured (the router's
    completion hook stays a single ``is not None`` test).

**Overhead contract**: obs is opt-in and ``obs=None`` (the default
everywhere) is a no-op stub path — consumers hold ``trace = obs.trace if
obs else None`` and guard each hook with one ``is not None`` test, so the
disabled path allocates no span objects and performs no metric work
(asserted by ``tests/test_obs.py``); the enabled path must cost <= 5% of
``bench_serve_batch`` requests/sec (asserted as an ERROR row, measured
overhead recorded in ``BENCH_serve.json``).  Analysis is snapshot-time
only — ``CriticalPathAnalyzer`` reads the ring lazily and adds nothing to
the request path.

``collect_all()`` is the one entry point that merges every adopted island;
``write_snapshot(dir)`` dumps ``metrics.json`` (flat metrics + per-interval
perf rows + the analysis blame table + SLO state, schema-versioned) plus
``trace.jsonl``, ``trace_chrome.json``, and ``crit_path.md`` — the
artifacts ``repro.launch.serve --metrics-dir`` emits and CI uploads next
to the ``BENCH_*.json`` history.
"""

from __future__ import annotations

import json
import os
from datetime import datetime, timezone
from typing import Dict, Optional, Sequence

from .analyze import SEGMENTS, CriticalPathAnalyzer, decompose_request
from .perf import PerfMeter, sim_perf_rows, sim_perf_summary
from .registry import (SCHEMA_VERSION, Counter, Gauge, MetricsRegistry,
                       P2Quantile, WindowedHistogram, nearest_rank_index,
                       stats_snapshot)
from .slo import SLOBoard, SLOSpec, SLOTracker, parse_slo_specs
from .trace import PARITY_PHASES, TraceBuffer

__all__ = [
    "Counter",
    "CriticalPathAnalyzer",
    "Gauge",
    "MetricsRegistry",
    "Observability",
    "P2Quantile",
    "PARITY_PHASES",
    "PerfMeter",
    "SCHEMA_VERSION",
    "SEGMENTS",
    "SLOBoard",
    "SLOSpec",
    "SLOTracker",
    "TraceBuffer",
    "WindowedHistogram",
    "decompose_request",
    "nearest_rank_index",
    "parse_slo_specs",
    "sim_perf_rows",
    "sim_perf_summary",
    "stats_snapshot",
]


class Observability:
    """Registry + tracer + perf reducer + analyzer (+ SLO board), wired."""

    def __init__(
        self,
        trace_maxlen: int = 65536,
        perf_interval_s: float = 1.0,
        baseline_service_s: Optional[float] = None,
        trace_sample: int = 1,
        slo_specs: Sequence[SLOSpec] = (),
    ):
        self.registry = MetricsRegistry()
        self.trace = TraceBuffer(maxlen=trace_maxlen, sample=trace_sample)
        self.perf = PerfMeter(interval_s=perf_interval_s,
                              baseline_service_s=baseline_service_s)
        self.analyze = CriticalPathAnalyzer(self.trace)
        self.registry.register_source("perf", self.perf)
        self.registry.register_source("trace", self.trace)
        self.registry.register_source("analyze", self.analyze)
        # None (not an empty board) when unconfigured so consumers keep the
        # one-guard stub pattern: `slo = obs.slo if obs is not None else None`
        # costs nothing per request when no objectives are declared.
        self.slo: Optional[SLOBoard] = None
        if slo_specs:
            self.slo = SLOBoard(slo_specs)
            self.registry.register_source("slo", self.slo)

    def collect_all(self) -> Dict[str, float]:
        """Every adopted island + instrument, one flat dotted namespace."""
        return self.registry.collect()

    def write_snapshot(self, out_dir: str, tag: str = "") -> Dict[str, str]:
        """Dump metrics + trace + analysis artifacts into ``out_dir``."""
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        metrics_path = os.path.join(out_dir, f"metrics{suffix}.json")
        jsonl_path = os.path.join(out_dir, f"trace{suffix}.jsonl")
        chrome_path = os.path.join(out_dir, f"trace_chrome{suffix}.json")
        crit_path = os.path.join(out_dir, f"crit_path{suffix}.md")
        doc = {
            "schema_version": SCHEMA_VERSION,
            "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
            "metrics": self.collect_all(),
            "perf_intervals": self.perf.interval_rows(),
            "analysis": {
                "blame": self.analyze.blame_table(),
                "top_slowest": self.analyze.top_slowest(5),
            },
        }
        if self.slo is not None:
            doc["slo"] = {"state": self.slo.snapshot(),
                          "firing": self.slo.firing()}
        with open(metrics_path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        self.trace.to_jsonl(jsonl_path)
        self.trace.write_chrome_trace(chrome_path)
        with open(crit_path, "w") as f:
            f.write(self.analyze.report_markdown())
        return {"metrics": metrics_path, "trace_jsonl": jsonl_path,
                "trace_chrome": chrome_path, "crit_path": crit_path}
