"""Metrics registry: one namespace over every ``*Stats`` island.

The runtime accumulates telemetry in nine disconnected dataclasses
(``RouterStats``, ``TransferStats``, ``CoherenceStats``, ``PrefetchStats``,
``MirrorStats``, ``WarmStartStats``, ``SchedulerStats``, ``CacheStats``,
``ServeStats``) plus per-store counters on ``TieredStore``.  Each of those
stays the *owner* of its numbers — the registry never copies or
double-counts; it adopts each island as a **source** through one shared
protocol:

    source.snapshot() -> Dict[str, float]     # relative dotted names

and prefixes the source's metrics with its plane name at collect time, so
``TransferStats.bytes_from_peers`` surfaces as ``transfer.bytes.peer`` and
``RouterStats.hit_rate`` as ``router.hit_rate`` in one flat, stable
namespace.  ``stats_snapshot`` is the generic implementation the dataclass
islands share: numeric fields, numeric-valued dict fields (flattened one
level), declared properties, and a per-class rename map for names whose
wire form differs from the attribute (``bytes_from_peers`` ->
``bytes.peer``).

On top of adopted sources the registry carries its own instruments —
``Counter``, ``Gauge``, and ``WindowedHistogram`` (ring-buffered samples
with streaming lifetime sum/min/max plus P² lifetime quantile estimates,
so the mean and ``est_p50``/``est_p99`` survive window wraps while
``win_p50``/``win_p99`` stay exact-but-window-only) — for values no island
owns, e.g. the live DES sample gauges.

Everything here is dependency-free (stdlib only): the runtime, core, and
diffusion planes import helpers from this module without cycles.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "P2Quantile",
    "SCHEMA_VERSION",
    "WindowedHistogram",
    "nearest_rank_index",
    "stats_snapshot",
]

# Version of the exported metrics/trace/BENCH document schema.  Bump when a
# metric is renamed or an export layout changes so downstream consumers of
# the JSON artifacts can dispatch on it.
SCHEMA_VERSION = 1


def nearest_rank_index(pct: float, n: int) -> int:
    """Index of the nearest-rank ``pct`` percentile in a sorted n-sample.

    The standard definition: rank ``ceil(pct * n)`` (1-based), clamped.
    ``int(pct * n)`` — the formula this replaces — is one too high whenever
    ``pct * n`` lands on an integer (p50 of 2 samples picked the *max*),
    which is exactly the small-sample regime the DES's peak-throughput
    summary runs in.  ``pct`` is a fraction in (0, 1].
    """
    if n <= 0:
        raise ValueError("empty sample has no percentile")
    return min(n - 1, max(0, math.ceil(pct * n) - 1))


def stats_snapshot(
    stats: Any,
    props: Tuple[str, ...] = (),
    rename: Optional[Dict[str, str]] = None,
) -> Dict[str, float]:
    """Generic ``snapshot()`` body for a ``*Stats`` dataclass.

    Emits every int/float field, flattens numeric-valued dict fields one
    level (``hits_by_tier`` -> ``hits_by_tier.hbm``), appends the declared
    ``props`` (derived values like ``hit_rate``), and applies ``rename`` to
    map attribute names onto their stable wire names.  Non-numeric fields
    (lists, objects) are skipped — islands with structured members override
    or extend the result themselves.
    """
    rename = rename or {}
    out: Dict[str, float] = {}

    def put(name: str, value: Any) -> None:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            return
        out[rename.get(name, name)] = float(value)

    for f in dataclasses.fields(stats):
        v = getattr(stats, f.name)
        if isinstance(v, dict):
            for k, sub in sorted(v.items()):
                put(f"{f.name}.{k}", sub)
        else:
            put(f.name, v)
    for p in props:
        put(p, getattr(stats, p))
    return out


class P2Quantile:
    """Streaming quantile estimate: the P² piecewise-parabolic algorithm.

    Jain & Chlamtac (1985): five markers track the min, max, target
    quantile, and its two flanking mid-quantiles; each observation shifts
    marker *positions* by one and repairs marker *heights* with a
    piecewise-parabolic (falling back to linear) interpolation.  O(1) time
    and O(1) memory per observation — the estimate covers the *lifetime*
    stream, so it survives the ring wraps that make ``win_p50``/``win_p99``
    window-only.  Exact until five samples have arrived; approximate (and
    for smooth distributions, tight — pinned by test against exact
    percentiles on seeded streams) afterwards.
    """

    __slots__ = ("p", "count", "_q", "_n", "_np")

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0, 1): {p}")
        self.p = float(p)
        self.count = 0
        self._q: List[float] = []      # marker heights
        self._n: List[float] = []      # marker positions (0-based)
        self._np: List[float] = []     # desired marker positions

    def observe(self, x: float) -> None:
        self.count += 1
        q = self._q
        if self.count <= 5:
            q.append(x)
            q.sort()
            if self.count == 5:
                p = self.p
                self._n = [0.0, 1.0, 2.0, 3.0, 4.0]
                self._np = [0.0, 2.0 * p, 4.0 * p, 2.0 + 2.0 * p, 4.0]
            return
        n, np_ = self._n, self._np
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 3
            for i in range(1, 4):
                if x < q[i]:
                    k = i - 1
                    break
        for i in range(k + 1, 5):
            n[i] += 1.0
        p = self.p
        np_[1] += p / 2.0
        np_[2] += p
        np_[3] += (1.0 + p) / 2.0
        np_[4] += 1.0
        for i in (1, 2, 3):
            d = np_[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or \
               (d <= -1.0 and n[i - 1] - n[i] < -1.0):
                d = 1.0 if d > 0 else -1.0
                # piecewise-parabolic (P²) height update …
                qp = q[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
                if not q[i - 1] < qp < q[i + 1]:
                    # … unless it would leave the bracket: linear repair
                    j = i + (1 if d > 0 else -1)
                    qp = q[i] + d * (q[j] - q[i]) / (n[j] - n[i])
                q[i] = qp
                n[i] += d

    @property
    def value(self) -> float:
        """Current estimate (exact nearest-rank below five samples)."""
        if self.count == 0:
            return 0.0
        if self.count <= 5:      # still exact: markers not yet adjusted
            return self._q[nearest_rank_index(self.p, len(self._q))]
        return self._q[2]


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class WindowedHistogram:
    """Ring buffer of samples + streaming lifetime aggregates.

    Percentiles are **window-only** (exact over the most recent ``maxlen``
    samples — the name says so: ``window_percentile``); ``mean``/``min``/
    ``max``/``sum``/``count`` are lifetime-true streaming values that
    survive ring wraps.
    """

    __slots__ = ("name", "maxlen", "_buf", "_next", "count", "sum",
                 "lifetime_min", "lifetime_max", "_p2_50", "_p2_99")

    def __init__(self, name: str, maxlen: int = 4096):
        self.name = name
        self.maxlen = int(maxlen)
        self._buf: List[float] = []
        self._next = 0
        self.count = 0
        self.sum = 0.0
        self.lifetime_min = math.inf
        self.lifetime_max = -math.inf
        # Lifetime-stream P² estimators complement the exact-but-window-only
        # sorted percentiles.
        self._p2_50 = P2Quantile(0.50)
        self._p2_99 = P2Quantile(0.99)

    def observe(self, x: float) -> None:
        self.count += 1
        self.sum += x
        if x < self.lifetime_min:
            self.lifetime_min = x
        if x > self.lifetime_max:
            self.lifetime_max = x
        self._p2_50.observe(x)
        self._p2_99.observe(x)
        if len(self._buf) < self.maxlen:
            self._buf.append(x)
        else:
            self._buf[self._next] = x
            self._next = (self._next + 1) % self.maxlen

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[float]:
        return iter(self._buf)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def window_percentile(self, pct: float) -> float:
        """Exact percentile over the retained window only (NOT lifetime)."""
        if not self._buf:
            return 0.0
        xs = sorted(self._buf)
        return xs[nearest_rank_index(pct / 100.0, len(xs))]

    def snapshot(self) -> Dict[str, float]:
        out = {
            "count": float(self.count),
            "sum": self.sum,
            "mean": self.mean,
            "window": float(len(self._buf)),
            "win_p50": self.window_percentile(50.0),
            "win_p99": self.window_percentile(99.0),
            "est_p50": self._p2_50.value,
            "est_p99": self._p2_99.value,
        }
        if self.count:
            out["min"] = self.lifetime_min
            out["max"] = self.lifetime_max
        return out


class MetricsRegistry:
    """Named instruments + adopted ``snapshot()`` sources, one namespace.

    ``collect()`` returns a flat ``{dotted_name: value}`` dict: every
    registered source's snapshot under its prefix, then every owned
    instrument under its own name.  A prefix can be re-registered (the
    latest source wins) so a rebuilt plane replaces its predecessor instead
    of double-reporting.
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, WindowedHistogram] = {}
        # prefix -> source with .snapshot(); insertion-ordered for stable
        # collect output.
        self._sources: Dict[str, Any] = {}

    # -- instruments ---------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, maxlen: int = 4096) -> WindowedHistogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = WindowedHistogram(name, maxlen)
        return h

    # -- sources -------------------------------------------------------------
    def register_source(self, prefix: str, source: Any) -> None:
        """Adopt a ``*Stats`` island (anything with ``snapshot() -> dict``).

        The island stays authoritative; the registry reads it lazily at
        ``collect()`` so nothing is double-counted.
        """
        if not callable(getattr(source, "snapshot", None)):
            raise TypeError(
                f"source for {prefix!r} has no snapshot() method: {source!r}")
        self._sources[prefix] = source

    def register_callable(self, prefix: str, fn: Callable[[], Dict[str, float]]) -> None:
        """Adopt a plain callable producing a snapshot dict (aggregates)."""
        self._sources[prefix] = _CallableSource(fn)

    def sources(self) -> List[str]:
        return list(self._sources)

    # -- collection ----------------------------------------------------------
    def collect(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for prefix, source in self._sources.items():
            for k, v in source.snapshot().items():
                out[f"{prefix}.{k}"] = v
        for c in self._counters.values():
            out[c.name] = c.value
        for g in self._gauges.values():
            out[g.name] = g.value
        for h in self._histograms.values():
            for k, v in h.snapshot().items():
                out[f"{h.name}.{k}"] = v
        return out


class _CallableSource:
    __slots__ = ("_fn",)

    def __init__(self, fn: Callable[[], Dict[str, float]]):
        self._fn = fn

    def snapshot(self) -> Dict[str, float]:
        return self._fn()
