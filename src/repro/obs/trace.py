"""Per-request trace spans: the causal story of one request, ring-buffered.

A span is one completed phase of a request's life — recorded *once, at its
end*, as a plain tuple (no open-span mutation on the hot path, no dict
allocation per request; at ~10k requests/sec the serving path has a
microsecond-scale budget per request for observability):

    (seq, request_id, name, phase, parent, start_s, end_s, replica, detail)

``request_id`` is the causal key: every span of one request carries it, so
the propagation chain the batch plane needs — router enqueue → dispatch
decision → tier-promotion replay → transfer flight → payload move →
completion — reassembles by id.  ``parent`` is the *phase name* of the span
this one is causally nested under ("request" ← "dispatch" ← "transfer"),
which keeps edges stable across drain modes (batched span seq ordering
differs from looped by design; names do not).  Batch-level spans that have
no single owning request (the drain scan itself, the coalesced promotion
replay, speculative flights) carry ``request_id = -1``.

Phases split into two classes:

  * **parity phases** (``PARITY_PHASES``): "request", "dispatch",
    "transfer" — one span per request event in *both* drain modes, with
    identical per-request hit/miss attribution.  ``parity_digest()``
    canonicalizes exactly these, so ``bench_serve_batch`` can assert the
    batched drain's span DAG ≡ the looped path's the same way it asserts
    assignment logs.
  * **structural phases**: "drain", "promote", "flight", "payload",
    "sample" — artifacts of *how* the work was executed (a batched drain
    coalesces promotions; speculative flights depend on queue timing).
    Excluded from the digest, included in every export.

Exports: ``to_jsonl()`` (one span dict per line) and ``to_chrome_trace()``
(Chrome ``traceEvents`` / Perfetto-loadable JSON: complete "X" events with
``tid`` = replica lane, so a batched drain renders as one visible wave
across the replica lanes).

**Sampling** (``sample=N``): batch-level structural spans — exactly the
``request_id = -1`` class: drain scans, coalesced promotion replays,
engine flights, batch payload moves, DES sample ticks — are recorded
1-in-N.  Request-attributed spans (any phase with ``request_id >= 0``,
which includes every parity phase and the per-request promote/payload
segments the critical-path analyzer consumes) are *always* recorded, so
``parity_digest()`` and ``obs.analyze`` attribution are byte-identical at
any sampling rate; only the how-was-it-executed volume thins out.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = ["PARITY_PHASES", "TraceBuffer"]

PARITY_PHASES = ("request", "dispatch", "transfer")

# Record layout indices (kept as a tuple for hot-path cheapness).
_SEQ, _RID, _NAME, _PHASE, _PARENT, _T0, _T1, _REPLICA, _DETAIL = range(9)


class TraceBuffer:
    """Fixed-capacity ring of span records (oldest overwritten)."""

    __slots__ = ("maxlen", "sample", "_buf", "_seq", "_struct_seen",
                 "_sampled_out", "_t0_min")

    def __init__(self, maxlen: int = 65536, sample: int = 1):
        self.maxlen = int(maxlen)
        self.sample = max(1, int(sample))   # 1-in-N for structural rid=-1 spans
        # Bounded deque: C-level oldest-first eviction keeps record() free
        # of ring-index branches on the per-span hot path.
        self._buf: Deque[Tuple] = deque(maxlen=self.maxlen)
        self._seq = 0           # lifetime span count (ids are unique)
        self._struct_seen = 0   # structural spans offered (sampled or not)
        self._sampled_out = 0   # structural spans the sampler dropped
        # Earliest start ever *recorded* — the stable Chrome-trace origin.
        # The ring overwrites old spans, so deriving the origin from the
        # surviving minimum shifts every exported timestamp after a wrap;
        # this anchor never moves once set (tracked at record() time).
        self._t0_min = float("inf")

    def record(
        self,
        request_id: int,
        name: str,
        phase: str,
        start_s: float,
        end_s: float,
        replica: str = "",
        parent: str = "",
        detail: Tuple = (),
    ) -> int:
        """Append one completed span; returns its sequence id (-1: sampled out)."""
        if request_id < 0 and self.sample > 1:
            self._struct_seen += 1
            if self._struct_seen % self.sample:
                self._sampled_out += 1
                return -1
        seq = self._seq
        self._seq = seq + 1
        if start_s < self._t0_min:
            self._t0_min = start_s
        self._buf.append((seq, request_id, name, phase, parent, start_s,
                          end_s, replica, detail))
        return seq

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def total(self) -> int:
        """Lifetime spans recorded (>= len() once the ring wraps)."""
        return self._seq

    def spans(self) -> List[Dict[str, Any]]:
        """Materialize the retained window as dicts, in record order."""
        out = []
        for rec in sorted(self._buf):        # seq order == causal record order
            out.append({
                "seq": rec[_SEQ],
                "request_id": rec[_RID],
                "name": rec[_NAME],
                "phase": rec[_PHASE],
                "parent": rec[_PARENT],
                "start_s": rec[_T0],
                "end_s": rec[_T1],
                "replica": rec[_REPLICA],
                "detail": list(rec[_DETAIL]),
            })
        return out

    def snapshot(self) -> Dict[str, float]:
        """Registry-source view: volume counters only."""
        return {"recorded": float(self._seq),
                "retained": float(len(self._buf)),
                "sampled_out": float(self._sampled_out)}

    # -- parity --------------------------------------------------------------
    def parity_digest(self) -> Dict[int, Tuple]:
        """Canonical per-request span DAG over the parity phases.

        Maps ``request_id`` to a sorted tuple of
        ``(phase, name, parent, replica, detail)`` — span counts, causal
        edges (parent links), and the per-request hit/miss attribution each
        span's detail carries.  Sequence ids and wall offsets are excluded:
        a batched drain interleaves record order differently by design, but
        the causal structure must be identical to the looped path's.

        Details are canonicalized here, not at record time: a dispatch
        span's per-object source map arrives in whichever insertion order
        its drain mode produced, and sorting it on the hot path would tax
        every request to make this snapshot-time comparison cheaper.
        """
        out: Dict[int, List[Tuple]] = {}
        for rec in self._buf:
            if rec[_RID] < 0 or rec[_PHASE] not in PARITY_PHASES:
                continue
            detail = rec[_DETAIL]
            if rec[_PHASE] == "dispatch" and len(detail) == 3 \
                    and isinstance(detail[2], tuple):
                detail = (detail[0], detail[1], tuple(sorted(detail[2])))
            out.setdefault(rec[_RID], []).append(
                (rec[_PHASE], rec[_NAME], rec[_PARENT], rec[_REPLICA],
                 detail))
        return {rid: tuple(sorted(entries)) for rid, entries in out.items()}

    # -- exports -------------------------------------------------------------
    def to_jsonl(self, path: str) -> int:
        """One span dict per line; returns the number written."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s) + "\n")
        return len(spans)

    def to_chrome_trace(self, time_origin_s: Optional[float] = None) -> Dict[str, Any]:
        """Chrome ``chrome://tracing`` / Perfetto document.

        Complete ("X") events on ``pid`` = phase class, ``tid`` = replica
        lane (unattributed spans ride a lane named after their phase).
        Timestamps are microseconds relative to the earliest span *ever
        recorded* (not the earliest surviving one — after a ring wrap those
        differ, and an origin derived from survivors would shift every
        timestamp relative to an earlier export of the same run), so
        virtual-time traces load at t=0 and repeated exports stay aligned.
        """
        events = []
        recs = sorted(self._buf)
        if recs and time_origin_s is None:
            time_origin_s = self._t0_min
        for rec in recs:
            dur_us = max(0.0, (rec[_T1] - rec[_T0]) * 1e6)
            events.append({
                "name": rec[_NAME],
                "cat": rec[_PHASE],
                "ph": "X",
                "ts": (rec[_T0] - (time_origin_s or 0.0)) * 1e6,
                "dur": dur_us,
                "pid": 1,
                "tid": rec[_REPLICA] or rec[_PHASE],
                "args": {
                    "request_id": rec[_RID],
                    "parent": rec[_PARENT],
                    "detail": list(rec[_DETAIL]),
                },
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> int:
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])
