"""Per-request trace spans: the causal story of one request, ring-buffered.

A span is one completed phase of a request's life — recorded *once, at its
end*, as a plain tuple (no open-span mutation on the hot path, no dict
allocation per request; at ~10k requests/sec the serving path has a
microsecond-scale budget per request for observability):

    (seq, request_id, name, phase, parent, start_s, end_s, replica, detail)

``request_id`` is the causal key: every span of one request carries it, so
the propagation chain the batch plane needs — router enqueue → dispatch
decision → tier-promotion replay → transfer flight → payload move →
completion — reassembles by id.  ``parent`` is the *phase name* of the span
this one is causally nested under ("request" ← "dispatch" ← "transfer"),
which keeps edges stable across drain modes (batched span seq ordering
differs from looped by design; names do not).  Batch-level spans that have
no single owning request (the drain scan itself, the coalesced promotion
replay, speculative flights) carry ``request_id = -1``.

Phases split into two classes:

  * **parity phases** (``PARITY_PHASES``): "request", "dispatch",
    "transfer" — one span per request event in *both* drain modes, with
    identical per-request hit/miss attribution.  ``parity_digest()``
    canonicalizes exactly these, so ``bench_serve_batch`` can assert the
    batched drain's span DAG ≡ the looped path's the same way it asserts
    assignment logs.
  * **structural phases**: "drain", "promote", "flight", "payload",
    "sample" — artifacts of *how* the work was executed (a batched drain
    coalesces promotions; speculative flights depend on queue timing).
    Excluded from the digest, included in every export.

Exports: ``to_jsonl()`` (one span dict per line) and ``to_chrome_trace()``
(Chrome ``traceEvents`` / Perfetto-loadable JSON: complete "X" events with
``tid`` = replica lane, so a batched drain renders as one visible wave
across the replica lanes).
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["PARITY_PHASES", "TraceBuffer"]

PARITY_PHASES = ("request", "dispatch", "transfer")

# Record layout indices (kept as a tuple for hot-path cheapness).
_SEQ, _RID, _NAME, _PHASE, _PARENT, _T0, _T1, _REPLICA, _DETAIL = range(9)


class TraceBuffer:
    """Fixed-capacity ring of span records (oldest overwritten)."""

    __slots__ = ("maxlen", "_buf", "_next", "_seq")

    def __init__(self, maxlen: int = 65536):
        self.maxlen = int(maxlen)
        self._buf: List[Tuple] = []
        self._next = 0
        self._seq = 0           # lifetime span count (ids are unique)

    def record(
        self,
        request_id: int,
        name: str,
        phase: str,
        start_s: float,
        end_s: float,
        replica: str = "",
        parent: str = "",
        detail: Tuple = (),
    ) -> int:
        """Append one completed span; returns its sequence id."""
        seq = self._seq
        self._seq = seq + 1
        rec = (seq, request_id, name, phase, parent, start_s, end_s,
               replica, detail)
        buf = self._buf
        if len(buf) < self.maxlen:
            buf.append(rec)
        else:
            self._next = nxt = self._next % self.maxlen
            buf[nxt] = rec
            self._next = nxt + 1
        return seq

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def total(self) -> int:
        """Lifetime spans recorded (>= len() once the ring wraps)."""
        return self._seq

    def spans(self) -> List[Dict[str, Any]]:
        """Materialize the retained window as dicts, in record order."""
        out = []
        for rec in sorted(self._buf):        # seq order == causal record order
            out.append({
                "seq": rec[_SEQ],
                "request_id": rec[_RID],
                "name": rec[_NAME],
                "phase": rec[_PHASE],
                "parent": rec[_PARENT],
                "start_s": rec[_T0],
                "end_s": rec[_T1],
                "replica": rec[_REPLICA],
                "detail": list(rec[_DETAIL]),
            })
        return out

    def snapshot(self) -> Dict[str, float]:
        """Registry-source view: volume counters only."""
        return {"recorded": float(self._seq),
                "retained": float(len(self._buf))}

    # -- parity --------------------------------------------------------------
    def parity_digest(self) -> Dict[int, Tuple]:
        """Canonical per-request span DAG over the parity phases.

        Maps ``request_id`` to a sorted tuple of
        ``(phase, name, parent, replica, detail)`` — span counts, causal
        edges (parent links), and the per-request hit/miss attribution each
        span's detail carries.  Sequence ids and wall offsets are excluded:
        a batched drain interleaves record order differently by design, but
        the causal structure must be identical to the looped path's.
        """
        out: Dict[int, List[Tuple]] = {}
        for rec in self._buf:
            if rec[_RID] < 0 or rec[_PHASE] not in PARITY_PHASES:
                continue
            out.setdefault(rec[_RID], []).append(
                (rec[_PHASE], rec[_NAME], rec[_PARENT], rec[_REPLICA],
                 rec[_DETAIL]))
        return {rid: tuple(sorted(entries)) for rid, entries in out.items()}

    # -- exports -------------------------------------------------------------
    def to_jsonl(self, path: str) -> int:
        """One span dict per line; returns the number written."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s) + "\n")
        return len(spans)

    def to_chrome_trace(self, time_origin_s: Optional[float] = None) -> Dict[str, Any]:
        """Chrome ``chrome://tracing`` / Perfetto document.

        Complete ("X") events on ``pid`` = phase class, ``tid`` = replica
        lane (unattributed spans ride a lane named after their phase).
        Timestamps are microseconds relative to the earliest span so
        virtual-time traces load at t=0.
        """
        events = []
        recs = sorted(self._buf)
        if recs and time_origin_s is None:
            time_origin_s = min(r[_T0] for r in recs)
        for rec in recs:
            dur_us = max(0.0, (rec[_T1] - rec[_T0]) * 1e6)
            events.append({
                "name": rec[_NAME],
                "cat": rec[_PHASE],
                "ph": "X",
                "ts": (rec[_T0] - (time_origin_s or 0.0)) * 1e6,
                "dur": dur_us,
                "pid": 1,
                "tid": rec[_REPLICA] or rec[_PHASE],
                "args": {
                    "request_id": rec[_RID],
                    "parent": rec[_PARENT],
                    "detail": list(rec[_DETAIL]),
                },
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> int:
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])
