"""The paper's evaluation metrics, computed live on the serving path.

The DES already derives the paper's Section-5.2 metrics offline
(``SimResult.performance_index_raw`` / ``speedup_vs`` / per-interval
throughput and utilization series); the live router had none of them.
``PerfMeter`` is the sliding-window reducer that closes the gap: the router
feeds it one event per completion and one sample per tick, and it maintains

  * **per-interval rows** — ``perf.throughput_rps``, ``perf.utilization``,
    ``perf.hit_rate``, ``perf.completed`` per fixed interval (the live
    analogue of the DES's ``TimePoint`` series — same names via
    ``sim_perf_rows``, so sim-vs-live curves overlay directly);
  * **lifetime aggregates** — ``perf.speedup``, ``perf.performance_index``,
    ``perf.resource_hours``, ``perf.utilization``.

Live definitions (documented in ``docs/metrics.md``):

  * ``baseline_service_s`` — mean service time of the requests that got
    *nothing* from the cache plane (all objects missed: the live analogue
    of the paper's first-available baseline, measured in-band).  A caller
    with a calibrated baseline passes it explicitly instead.
  * ``speedup`` — ``baseline_service_s * completed / busy_seconds``: the
    work accomplished, priced in baseline cost, over the replica-busy time
    actually spent.  1.0 when caching contributes nothing, >1 as hits
    replace full-cost service.  (The DES's ``speedup_vs`` divides two
    measured makespans; live serving has no second run, so the baseline is
    priced per-request.)
  * ``performance_index`` — ``speedup / resource_hours`` with
    ``resource_hours`` the integral of registered replicas over time: the
    DES's ``performance_index_raw`` (speedup per CPU-hour), identically
    named and unit-compatible.

``sim_perf_rows`` / ``sim_perf_summary`` project a finished ``SimResult``
into the same dotted namespace, and ``Simulator(obs=...)`` publishes the
live DES sample gauges under it while running.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional

from .registry import nearest_rank_index  # noqa: F401  (re-export: rank home)

__all__ = ["PerfMeter", "sim_perf_rows", "sim_perf_summary"]


class PerfMeter:
    """Sliding-interval reducer over completion events + utilization samples.

    Time is caller-supplied (virtual or wall, like the router); events may
    arrive with non-decreasing ``now``.  All hot-path methods are O(1).
    """

    def __init__(
        self,
        interval_s: float = 1.0,
        baseline_service_s: Optional[float] = None,
        max_intervals: int = 1024,
    ):
        self.interval_s = float(interval_s)
        self._inv_interval = 1.0 / self.interval_s
        self._fixed_baseline = baseline_service_s
        self.rows: deque = deque(maxlen=max_intervals)   # closed interval rows
        self._interval: Optional[int] = None             # open interval index
        # open-interval accumulators
        self._i_completed = 0
        self._i_hits = 0
        self._i_misses = 0
        self._i_busy_integral = 0.0      # busy-replica-seconds in interval
        self._i_replica_integral = 0.0   # registered-replica-seconds in interval
        # lifetime accumulators
        self.completed = 0
        self.hits = 0
        self.misses = 0
        self.busy_seconds = 0.0          # sum of per-request service time
        self._baseline_sum = 0.0         # all-miss request service times
        self._baseline_n = 0
        # resource integral (from samples)
        self._last_sample_t: Optional[float] = None
        self._last_replicas = 0.0
        self._last_busy = 0.0
        self.replica_seconds = 0.0
        self.busy_replica_seconds = 0.0

    # -- hot path ------------------------------------------------------------
    def on_complete(self, now: float, service_s: float, hits: int, misses: int) -> None:
        """One finished request: ``service_s`` is dispatch->finish time."""
        self._roll(now)
        self._i_completed += 1
        self._i_hits += hits
        self._i_misses += misses
        self.completed += 1
        self.hits += hits
        self.misses += misses
        self.busy_seconds += service_s
        if misses and not hits and self._fixed_baseline is None:
            # A request the cache plane did nothing for: the measured
            # baseline cost of serving without data diffusion.
            self._baseline_sum += service_s
            self._baseline_n += 1

    def on_sample(self, now: float, replicas: float, busy: float) -> None:
        """Pool utilization sample: ``busy`` replicas of ``replicas`` total."""
        self._roll(now)
        last = self._last_sample_t
        if last is not None and now > last:
            dt = now - last
            self.replica_seconds += self._last_replicas * dt
            self.busy_replica_seconds += self._last_busy * dt
            self._i_replica_integral += self._last_replicas * dt
            self._i_busy_integral += self._last_busy * dt
        self._last_sample_t = now
        self._last_replicas = replicas
        self._last_busy = busy

    # -- interval bookkeeping ------------------------------------------------
    def _roll(self, now: float) -> None:
        i = int(now * self._inv_interval)
        if i == self._interval:        # hot path: same open interval
            return
        if self._interval is None:
            self._interval = i
            return
        while self._interval < i:
            self._close_interval()
            self._interval += 1

    def _close_interval(self) -> None:
        util = (self._i_busy_integral / self._i_replica_integral
                if self._i_replica_integral > 0 else 0.0)
        accesses = self._i_hits + self._i_misses
        self.rows.append({
            "t": self._interval * self.interval_s,
            "perf.throughput_rps": self._i_completed / self.interval_s,
            "perf.utilization": util,
            "perf.hit_rate": self._i_hits / accesses if accesses else 0.0,
            "perf.completed": float(self._i_completed),
        })
        self._i_completed = self._i_hits = self._i_misses = 0
        self._i_busy_integral = self._i_replica_integral = 0.0

    # -- derived -------------------------------------------------------------
    @property
    def baseline_service_s(self) -> float:
        if self._fixed_baseline is not None:
            return self._fixed_baseline
        if self._baseline_n:
            return self._baseline_sum / self._baseline_n
        return 0.0

    @property
    def speedup(self) -> float:
        base = self.baseline_service_s
        if base <= 0.0 or self.busy_seconds <= 0.0:
            return 1.0
        return base * self.completed / self.busy_seconds

    @property
    def resource_hours(self) -> float:
        return self.replica_seconds / 3600.0

    @property
    def performance_index(self) -> float:
        rh = self.resource_hours
        return self.speedup / rh if rh > 0 else 0.0

    @property
    def utilization(self) -> float:
        return (self.busy_replica_seconds / self.replica_seconds
                if self.replica_seconds > 0 else 0.0)

    def interval_rows(self) -> List[Dict[str, float]]:
        """Closed per-interval rows, oldest first (bounded window)."""
        return list(self.rows)

    def snapshot(self) -> Dict[str, float]:
        """Registry-source view (lifetime aggregates; prefixed ``perf.``)."""
        elapsed_rows = len(self.rows)
        return {
            "performance_index": self.performance_index,
            "speedup": self.speedup,
            "utilization": self.utilization,
            "resource_hours": self.resource_hours,
            "completed": float(self.completed),
            "hits": float(self.hits),
            "misses": float(self.misses),
            "busy_seconds": self.busy_seconds,
            "baseline_service_s": self.baseline_service_s,
            "baseline_samples": float(self._baseline_n),
            "intervals": float(elapsed_rows),
        }


# -- DES projection (shared names) -------------------------------------------
def sim_perf_rows(result: Any) -> List[Dict[str, float]]:
    """Per-interval rows from a ``SimResult`` series, in the live namespace.

    Key-compatible with ``PerfMeter.interval_rows`` where semantics match
    (``perf.utilization``) and explicitly unit-suffixed where they differ
    (the DES measures byte throughput: ``perf.throughput_gbps``).
    """
    dt = max(1e-9, result.config.sample_dt_s)
    rows = []
    for tp in result.series:
        rows.append({
            "t": tp.t,
            "perf.throughput_gbps": sum(tp.throughput_bytes.values()) * 8 / 1e9 / dt,
            "perf.utilization": tp.cpu_util,
            "perf.queue_len": float(tp.queue_len),
            "perf.nodes": float(tp.nodes),
        })
    return rows


def sim_perf_summary(result: Any, baseline_wet_s: Optional[float] = None) -> Dict[str, float]:
    """Lifetime aggregates from a ``SimResult``, in the live namespace."""
    out = {
        "perf.utilization": result.avg_cpu_util,
        "perf.throughput_gbps": result.avg_throughput_gbps,
        "perf.resource_hours": result.cpu_time_hours,
        "perf.completed": float(result.tasks_done),
        "perf.hit_rate": result.hit_rate_local + result.hit_rate_remote,
    }
    if baseline_wet_s is not None:
        out["perf.speedup"] = result.speedup_vs(baseline_wet_s)
        out["perf.performance_index"] = result.performance_index_raw(baseline_wet_s)
    return out
