"""Critical-path attribution: where each request's wall time actually went.

``TraceBuffer`` records the causal span chain of every request (root
"request" span ← "dispatch" decision ← "transfer"/"promote"/"payload"
children).  This module reconstructs that chain per request and decomposes
the root span's wall time into **non-overlapping segments** that sum
exactly to the request's response time:

    queue                submit -> dispatch decision, nothing active
    dispatch             width of the dispatch-decision span(s) themselves
    promote              lower-tier hit swap-in (tier promotion toward HBM)
    transfer_peer        data diffusion over a peer NIC link
    transfer_persistent  cold read from the persistent store
    payload              measured byte movement (real-payload plane)
    service              post-dispatch time not covered by any data span
                         (compute + anything uninstrumented)

The decomposition is a boundary sweep: every instant of ``[submit,
finish]`` is attributed to exactly one segment — the highest-priority
*active* child span covering it (``dispatch > promote > transfer_peer >
transfer_persistent > payload``), else "queue" before the dispatch
decision and "service" after.  Overlapping transfers therefore do not
double-count (the paper's restore costs are concurrent by design), and the
per-request segments sum to the request's wall time **by construction** —
property-tested on random span soups in ``tests/test_obs_analyze.py``.

Determinism contract: attribution is a pure function of the parity span
chain plus the request-attributed promote/payload spans, all of which are
recorded identically by the looped and batched drains (and never sampled
out — see ``TraceBuffer`` sampling).  ``attribution_digest()`` canonical-
izes the per-request decomposition so ``bench_serve_batch`` can assert the
batched drain blames the exact same segments as the looped path, one level
up from ``parity_digest()`` (which checks span structure; this checks the
*time accounting* derived from it).  Like the decision-parity gate, the
assertion applies to zero-stale-conversion regimes (the seeded Zipf
streams the bench drives; ``stale_snapshot_drops`` is asserted zero).

Stdlib-only, no repro imports beyond the sibling trace module.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .registry import nearest_rank_index

__all__ = ["SEGMENTS", "CriticalPathAnalyzer", "decompose_request"]

# Attribution order: when several child spans cover the same instant, the
# earliest segment in this tuple wins.  Any fixed order keeps the partition
# property; this one ranks the *scheduling* work above the data movement it
# triggers, and peer diffusion above the persistent fallback it replaces.
SEGMENTS = ("queue", "dispatch", "promote", "transfer_peer",
            "transfer_persistent", "payload", "service")

# Child phases that carry wall-time intervals, mapped to their segment
# (transfer resolves per-span on its source detail).
_PRIORITY = {"dispatch": 0, "promote": 1, "transfer_peer": 2,
             "transfer_persistent": 3, "payload": 4}


def _segment_of(span: Dict[str, Any]) -> Optional[str]:
    phase = span["phase"]
    if phase == "dispatch":
        return "dispatch"
    if phase == "promote":
        return "promote"
    if phase == "payload":
        return "payload"
    if phase == "transfer":
        detail = span.get("detail") or []
        src = str(detail[0]) if detail else ""
        return "transfer_peer" if src.startswith("peer") else "transfer_persistent"
    return None      # unknown/structural phase: falls into "service"


def decompose_request(root: Dict[str, Any],
                      children: List[Dict[str, Any]]) -> Dict[str, float]:
    """Partition one request's ``[submit, finish]`` into ``SEGMENTS``.

    ``root`` is the request's "request" span; ``children`` its same-id
    spans.  Returns ``{segment: seconds}`` over all seven segments (zeros
    included); the values sum to ``root.end_s - root.start_s`` exactly (up
    to float associativity — the property test allows 1e-9 slack).
    """
    t0, t1 = root["start_s"], root["end_s"]
    out = {seg: 0.0 for seg in SEGMENTS}
    if t1 <= t0:
        return out

    # Classified child intervals, clipped to the root span.
    intervals: List[Tuple[float, float, int]] = []
    dispatch_t: Optional[float] = None
    for sp in children:
        seg = _segment_of(sp)
        if seg is None:
            continue
        a, b = max(t0, sp["start_s"]), min(t1, sp["end_s"])
        if seg == "dispatch":
            d = a if dispatch_t is None else min(dispatch_t, a)
            dispatch_t = d
        if b > a:
            intervals.append((a, b, _PRIORITY[seg]))
    # No dispatch decision recorded (ring wrap ate it): everything
    # uncovered counts as queue — visibly wrong-shaped rather than a
    # silently optimistic "service".
    td = dispatch_t if dispatch_t is not None else t1

    cuts = {t0, t1, min(t1, max(t0, td))}
    for a, b, _prio in intervals:
        cuts.add(a)
        cuts.add(b)
    edges = sorted(cuts)
    seg_names = ("dispatch", "promote", "transfer_peer",
                 "transfer_persistent", "payload")
    for a, b in zip(edges, edges[1:]):
        mid_active: Optional[int] = None
        for ia, ib, prio in intervals:
            if ia <= a and b <= ib and (mid_active is None or prio < mid_active):
                mid_active = prio
        if mid_active is not None:
            out[seg_names[mid_active]] += b - a
        elif b <= td:
            out["queue"] += b - a
        else:
            out["service"] += b - a
    return out


class CriticalPathAnalyzer:
    """Lazy blame-table view over a ``TraceBuffer``.

    Recomputes from the live trace at call time (analysis is an offline /
    snapshot-time concern — nothing here runs on the request hot path).
    Requests whose root span was overwritten by the ring are skipped.
    """

    def __init__(self, trace: Any):
        self.trace = trace

    # -- per-request ---------------------------------------------------------
    def breakdowns(self) -> Dict[int, Dict[str, float]]:
        """``{request_id: {segment: seconds, "wall": seconds}}``."""
        roots: Dict[int, Dict[str, Any]] = {}
        kids: Dict[int, List[Dict[str, Any]]] = {}
        for sp in self.trace.spans():
            rid = sp["request_id"]
            if rid < 0:
                continue
            if sp["phase"] == "request":
                roots[rid] = sp
            else:
                kids.setdefault(rid, []).append(sp)
        out: Dict[int, Dict[str, float]] = {}
        for rid, root in roots.items():
            br = decompose_request(root, kids.get(rid, []))
            br["wall"] = root["end_s"] - root["start_s"]
            out[rid] = br
        return out

    # -- aggregates ----------------------------------------------------------
    def blame_table(self) -> Dict[str, Dict[str, float]]:
        """Per-segment ``{mean, win_p99, frac}`` over the retained window.

        ``frac`` is the segment's share of total wall time (all fracs sum
        to 1 when any wall time exists); ``win_p99`` is the nearest-rank
        p99 of the per-request segment values — window-only, like every
        ``win_``-prefixed metric.
        """
        brs = self.breakdowns()
        table: Dict[str, Dict[str, float]] = {}
        total_wall = sum(b["wall"] for b in brs.values())
        n = len(brs)
        for seg in SEGMENTS:
            vals = sorted(b[seg] for b in brs.values())
            total = sum(vals)
            table[seg] = {
                "mean": total / n if n else 0.0,
                "win_p99": vals[nearest_rank_index(0.99, n)] if n else 0.0,
                "frac": total / total_wall if total_wall > 0 else 0.0,
            }
        return table

    def attribution_digest(self, ndigits: int = 9) -> Dict[int, Tuple]:
        """Canonical per-request attribution for looped-vs-batched asserts.

        Zero segments are dropped and values rounded so the digest compares
        the *accounting*, not float noise from summation order.
        """
        out: Dict[int, Tuple] = {}
        for rid, br in self.breakdowns().items():
            out[rid] = tuple(sorted(
                (seg, round(br[seg], ndigits))
                for seg in SEGMENTS if br[seg] > 0.0))
        return out

    def top_slowest(self, k: int = 5) -> List[Dict[str, Any]]:
        """The ``k`` slowest retained requests with their dominant segment."""
        roots = {sp["request_id"]: sp for sp in self.trace.spans()
                 if sp["request_id"] >= 0 and sp["phase"] == "request"}
        rows = []
        for rid, br in self.breakdowns().items():
            top_seg = max(SEGMENTS, key=lambda s: br[s])
            rows.append({
                "request_id": rid,
                "replica": roots[rid]["replica"] if rid in roots else "",
                "wall_s": br["wall"],
                "top_segment": top_seg,
                "top_segment_s": br[top_seg],
                "segments": {s: br[s] for s in SEGMENTS if br[s] > 0.0},
            })
        rows.sort(key=lambda r: (-r["wall_s"], r["request_id"]))
        return rows[:k]

    # -- exports -------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Registry-source view: ``crit.<segment>.{mean,win_p99,frac}``."""
        out: Dict[str, float] = {}
        table = self.blame_table()
        out["requests"] = float(len(self.breakdowns()))
        for seg in SEGMENTS:
            for stat, v in table[seg].items():
                out[f"crit.{seg}.{stat}"] = v
        return out

    def report_markdown(self, top_k: int = 5) -> str:
        """Human-readable blame table + top-K slowest requests."""
        brs = self.breakdowns()
        table = self.blame_table()
        lines = [
            "# Critical-path attribution",
            "",
            f"Requests analyzed (retained window): {len(brs)}",
            "",
            "| segment | mean (s) | win_p99 (s) | frac |",
            "|---|---:|---:|---:|",
        ]
        for seg in SEGMENTS:
            row = table[seg]
            lines.append(f"| {seg} | {row['mean']:.6f} | "
                         f"{row['win_p99']:.6f} | {row['frac']:.3f} |")
        lines += ["", f"## Top {top_k} slowest requests", "",
                  "| request | replica | wall (s) | dominant segment |",
                  "|---|---|---:|---|"]
        for r in self.top_slowest(top_k):
            lines.append(
                f"| {r['request_id']} | {r['replica']} | {r['wall_s']:.6f} "
                f"| {r['top_segment']} ({r['top_segment_s']:.6f}s) |")
        return "\n".join(lines) + "\n"
