"""Declarative SLOs: error budgets and multi-window burn-rate alerts.

An ``SLOSpec`` states an objective over the live request stream:

    latency       fraction of requests answering within ``threshold_s``
                  must be >= ``target``    (e.g. p99 <= 50ms <=> target
                  0.99, threshold_s 0.05)
    hit_rate      object-level cache hit fraction must be >= ``target``
    availability  fraction of requests completing without failure must
                  be >= ``target``

Each spec compiles to an ``SLOTracker`` that counts good/bad events in two
time-bucketed rolling windows (a fast one for detection latency, a slow
one for confidence) plus lifetime error-budget accounting.  The alert rule
is the standard multi-window burn rate: with ``burn = bad_frac / (1 -
target)`` (1.0 = consuming budget exactly as fast as the objective
allows), the alert **fires** when *both* windows burn at >=
``fire_burn``, and **clears** only when *both* fall to <= ``fire_burn *
clear_frac``.  Between those bounds the state *holds* — the same dead-band
shape as ``CoherenceBus.adapt`` (fire above target, clear below target/2,
hold between), so a burn rate oscillating around the threshold cannot
flap the alert.

Clock discipline matches the rest of the runtime: callers pass ``now``
explicitly, so the DES drives SLO windows in virtual time and the serve
loop in wall-clock with the same code.

Stdlib-only; no repro imports.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence

__all__ = ["SLOBoard", "SLOSpec", "SLOTracker", "parse_slo_specs"]

_KINDS = ("latency", "hit_rate", "availability")


@dataclass(frozen=True)
class SLOSpec:
    """One declarative objective (see module docstring for kinds)."""

    name: str
    kind: str                    # "latency" | "hit_rate" | "availability"
    target: float                # good-fraction objective in (0, 1)
    threshold_s: float = 0.0     # latency kind only: the "good" bound
    fast_window_s: float = 60.0
    slow_window_s: float = 600.0
    fire_burn: float = 2.0       # fire when both windows burn >= this
    clear_frac: float = 0.5      # clear when both burn <= fire_burn * this

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r} (want {_KINDS})")
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"SLO target must be in (0, 1): {self.target}")
        if self.kind == "latency" and self.threshold_s <= 0.0:
            raise ValueError("latency SLO needs threshold_s > 0")
        if self.fast_window_s >= self.slow_window_s:
            raise ValueError("fast window must be shorter than slow window")


class _RollingWindow:
    """Good/bad event counts over the trailing ``window_s`` seconds.

    Time-bucketed (``buckets`` sub-intervals) so memory is O(buckets)
    regardless of event rate; counts age out a bucket at a time.  Running
    sums are maintained incrementally — ``observe`` and ``totals`` are
    O(1) amortized (eviction pops each bucket once), because this sits on
    the router's per-request completion path.
    """

    __slots__ = ("bucket_s", "buckets", "good", "bad", "_dq")

    def __init__(self, window_s: float, buckets: int = 12):
        self.buckets = int(buckets)
        self.bucket_s = float(window_s) / self.buckets
        self.good = 0.0          # running in-window totals
        self.bad = 0.0
        # (bucket_index, good, bad), ascending index.
        self._dq: Deque[List[float]] = deque()

    def _evict(self, idx: int) -> None:
        dq = self._dq
        floor = idx - self.buckets + 1
        while dq and dq[0][0] < floor:
            _, g, b = dq.popleft()
            self.good -= g
            self.bad -= b

    def observe(self, now: float, good: float, bad: float) -> None:
        self.observe_bucket(int(now / self.bucket_s), good, bad)

    def observe_bucket(self, idx: int, good: float, bad: float) -> None:
        """Feed a pre-bucketed count (``SLOTracker`` flushes whole buckets)."""
        dq = self._dq
        self.good += good
        self.bad += bad
        if dq and dq[-1][0] == idx:
            dq[-1][1] += good
            dq[-1][2] += bad
        else:
            dq.append([idx, good, bad])
            self._evict(idx)

    def totals(self, now: float) -> tuple:
        self._evict(int(now / self.bucket_s))
        return self.good, self.bad


class SLOTracker:
    """Live state of one ``SLOSpec``: windows, budget, alert latch."""

    __slots__ = ("spec", "fast", "slow", "good_total", "bad_total",
                 "firing", "fired_count", "cleared_count", "_last_now",
                 "_inv_bucket", "_cur_idx", "_cur_good", "_cur_bad")

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self.fast = _RollingWindow(spec.fast_window_s)
        # The slow window shares the fast window's bucket granularity so
        # one flushed bucket feeds both (memory stays O(buckets), ~120 for
        # the default 600s/5s pair).
        self.slow = _RollingWindow(
            spec.slow_window_s,
            buckets=max(1, round(spec.slow_window_s / self.fast.bucket_s)))
        self.good_total = 0.0
        self.bad_total = 0.0
        self.firing = False
        self.fired_count = 0      # transitions into firing (not event count)
        self.cleared_count = 0
        self._last_now = 0.0
        self._inv_bucket = 1.0 / self.fast.bucket_s
        self._cur_idx: Optional[int] = None     # open (unflushed) bucket
        self._cur_good = 0.0
        self._cur_bad = 0.0

    def observe(self, now: float, good: float, bad: float) -> None:
        # Per-request cost is one multiply, one compare, and four adds:
        # events accumulate into the open fast bucket and flush into the
        # rolling windows only when it turns over — the window aggregates
        # cannot move before that, so neither can the alert latch, and
        # this sits on the router's per-request completion path.
        # ``snapshot()`` (and any burn query) flushes and re-judges on
        # demand, so the exported state is never stale.
        self.good_total += good
        self.bad_total += bad
        self._last_now = now
        idx = int(now * self._inv_bucket)
        if idx != self._cur_idx:
            self._flush()
            self._cur_idx = idx
            self._update_alert(now)
        self._cur_good += good
        self._cur_bad += bad

    def _flush(self) -> None:
        """Push the open bucket's counts into both rolling windows."""
        g, b = self._cur_good, self._cur_bad
        if g or b:
            idx = self._cur_idx
            self.fast.observe_bucket(idx, g, b)
            self.slow.observe_bucket(idx, g, b)
            self._cur_good = 0.0
            self._cur_bad = 0.0

    @staticmethod
    def _burn(good: float, bad: float, target: float) -> float:
        total = good + bad
        if total <= 0.0:
            return 0.0
        return (bad / total) / (1.0 - target)

    def burn_rates(self, now: Optional[float] = None) -> tuple:
        now = self._last_now if now is None else now
        self._flush()
        t = self.spec.target
        return (self._burn(*self.fast.totals(now), t),
                self._burn(*self.slow.totals(now), t))

    def _update_alert(self, now: float) -> None:
        # Bounded dead-band controller (CoherenceBus.adapt shape): fire
        # above fire_burn on BOTH windows, clear below fire_burn*clear_frac
        # on BOTH, hold state in the band between.
        fast_b, slow_b = self.burn_rates(now)
        spec = self.spec
        if not self.firing and fast_b >= spec.fire_burn and slow_b >= spec.fire_burn:
            self.firing = True
            self.fired_count += 1
        elif self.firing and fast_b <= spec.fire_burn * spec.clear_frac \
                and slow_b <= spec.fire_burn * spec.clear_frac:
            self.firing = False
            self.cleared_count += 1

    @property
    def budget_remaining(self) -> float:
        """Lifetime error budget left, in [0, 1] (1 = untouched)."""
        total = self.good_total + self.bad_total
        if total <= 0.0:
            return 1.0
        allowed = (1.0 - self.spec.target) * total
        if allowed <= 0.0:
            return 0.0 if self.bad_total else 1.0
        return max(0.0, min(1.0, 1.0 - self.bad_total / allowed))

    def snapshot(self) -> Dict[str, float]:
        self._update_alert(self._last_now)      # judge the latch on demand
        fast_b, slow_b = self.burn_rates()
        return {
            "target": self.spec.target,
            "good": self.good_total,
            "bad": self.bad_total,
            "burn_fast": fast_b,
            "burn_slow": slow_b,
            "firing": 1.0 if self.firing else 0.0,
            "fired_count": float(self.fired_count),
            "cleared_count": float(self.cleared_count),
            "budget_remaining": self.budget_remaining,
        }


class SLOBoard:
    """All configured SLOs, fed from the router's completion path.

    ``on_complete`` fans one finished request out to every tracker whose
    kind can judge it; ``record_failure`` marks an availability breach.
    Registered as the ``slo`` metrics source, so every tracker surfaces as
    ``slo.<name>.{firing,burn_fast,burn_slow,budget_remaining,...}``.
    """

    def __init__(self, specs: Sequence[SLOSpec] = ()):
        self.trackers: Dict[str, SLOTracker] = {
            s.name: SLOTracker(s) for s in specs}
        # Kind-split lists: on_complete runs per completed request, so the
        # per-call work is a plain loop over prebuilt lists, no dispatch.
        trs = self.trackers.values()
        self._latency = tuple(t for t in trs if t.spec.kind == "latency")
        self._hit_rate = tuple(t for t in trs if t.spec.kind == "hit_rate")
        self._avail = tuple(t for t in trs if t.spec.kind == "availability")

    def __bool__(self) -> bool:
        return bool(self.trackers)

    def on_complete(self, now: float, latency_s: float,
                    hits: int = 0, misses: int = 0) -> None:
        for tr in self._latency:
            if latency_s <= tr.spec.threshold_s:
                tr.observe(now, 1.0, 0.0)
            else:
                tr.observe(now, 0.0, 1.0)
        if hits or misses:
            g, b = float(hits), float(misses)
            for tr in self._hit_rate:
                tr.observe(now, g, b)
        for tr in self._avail:          # availability: completion = good
            tr.observe(now, 1.0, 0.0)

    def record_failure(self, now: float) -> None:
        for tr in self.trackers.values():
            if tr.spec.kind == "availability":
                tr.observe(now, 0.0, 1.0)

    def signal(self, name: str) -> SLOTracker:
        """Queryable live signal for one objective (admission control /
        the multi-tenant arc read this, not the flattened metrics)."""
        return self.trackers[name]

    def firing(self) -> List[str]:
        return [n for n, tr in self.trackers.items() if tr.firing]

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for name, tr in self.trackers.items():
            for k, v in tr.snapshot().items():
                out[f"{name}.{k}"] = v
        return out


def parse_slo_specs(text: str) -> List[SLOSpec]:
    """Parse the CLI grammar: ``p99_ms=50:hit_rate=0.8:avail=0.999``.

    ``p<NN>_ms=X`` declares a latency objective (target NN/100, threshold
    X milliseconds); ``hit_rate=Y`` and ``avail=Z`` declare the other two
    kinds with fraction targets.  Colon-separated; order free.
    """
    specs: List[SLOSpec] = []
    for part in filter(None, (p.strip() for p in text.split(":"))):
        key, _, val = part.partition("=")
        if not val:
            raise ValueError(f"bad SLO clause {part!r} (want key=value)")
        if key.startswith("p") and key.endswith("_ms"):
            pct = float(key[1:-3])
            if not 0.0 < pct < 100.0:
                raise ValueError(f"bad latency percentile in {part!r}")
            specs.append(SLOSpec(
                name=f"p{key[1:-3]}_latency", kind="latency",
                target=pct / 100.0, threshold_s=float(val) / 1000.0))
        elif key == "hit_rate":
            specs.append(SLOSpec(name="hit_rate", kind="hit_rate",
                                 target=float(val)))
        elif key in ("avail", "availability"):
            specs.append(SLOSpec(name="availability", kind="availability",
                                 target=float(val)))
        else:
            raise ValueError(f"unknown SLO clause {part!r}")
    return specs
