"""Sharded, atomic, async checkpointing with resharding restore.

Layout:  <dir>/step_<n>/
           manifest.json       — tree structure, shapes, dtypes, file map
           arrays_<i>.npz      — flattened leaf payloads (split by size)
           _COMMITTED          — atomic commit marker (written last)

Properties needed at 1000+-node scale, realized here single-process:
  * atomic commit (readers only trust _COMMITTED checkpoints);
  * async save (a writer thread snapshots device arrays off the step path);
  * restore-with-resharding: arrays are saved unsharded-logical and
    re-placed under the CURRENT mesh's shardings at load — an elastic
    restart onto a different device count just works;
  * integrity: per-file sha256 in the manifest, verified on load.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

MAX_FILE_BYTES = 1 << 28  # 256 MiB per npz member group


def _to_raw(arr: np.ndarray) -> np.ndarray:
    """npz-safe byte view (npz mangles ml_dtypes like bfloat16)."""
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8)


def _from_raw(raw: np.ndarray, dtype: str, shape) -> np.ndarray:
    return raw.view(np.dtype(dtype)).reshape(shape)


# Public names for the raw byte-view pair: the KV spill tier
# (``diffusion.payload.RealPayload``) writes its chunked page files through
# the same dtype-safe serialization the checkpoint format uses, so bfloat16
# and friends round-trip identically in both planes.
to_raw_bytes = _to_raw
from_raw_bytes = _from_raw


def _tree_flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *, blocking: bool = True):
    """Write checkpoint for ``step``. Returns the checkpoint path."""
    paths, leaves, _ = _tree_flatten_with_paths(tree)
    host_leaves = [np.asarray(l) for l in leaves]  # device -> host snapshot

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest: Dict[str, Any] = {"step": step, "leaves": [], "files": {}}
        file_idx, file_bytes, bucket = 0, 0, {}

        def flush():
            nonlocal file_idx, file_bytes, bucket
            if not bucket:
                return
            fname = f"arrays_{file_idx}.npz"
            fpath = os.path.join(tmp, fname)
            np.savez(fpath, **bucket)
            with open(fpath, "rb") as f:
                manifest["files"][fname] = hashlib.sha256(f.read()).hexdigest()
            file_idx += 1
            file_bytes = 0
            bucket = {}

        for i, (path, leaf) in enumerate(zip(paths, host_leaves)):
            key = f"a{i}"
            manifest["leaves"].append(
                {"path": path, "key": key, "file": file_idx,
                 "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
            )
            bucket[key] = _to_raw(leaf)
            file_bytes += leaf.nbytes
            if file_bytes >= MAX_FILE_BYTES:
                flush()
        flush()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write(str(time.time()))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        return final

    if blocking:
        return write()
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


class AsyncCheckpointer:
    """Serializes async saves; ``wait()`` joins the in-flight write."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._inflight: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree) -> None:
        self.wait()
        self._inflight = save_checkpoint(self.directory, step, tree, blocking=False)
        self._gc()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _gc(self) -> None:
        steps = sorted(list_checkpoints(self.directory))
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


def list_checkpoints(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if name.startswith("step_") and os.path.exists(os.path.join(full, "_COMMITTED")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_checkpoint(directory: str) -> Optional[int]:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``target_tree``; optionally re-place
    every leaf under ``shardings`` (same pytree structure) — this is the
    elastic-resharding path (new mesh != save-time mesh)."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(ckpt, "_COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {ckpt}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    if verify:
        for fname, digest in manifest["files"].items():
            with open(os.path.join(ckpt, fname), "rb") as f:
                actual = hashlib.sha256(f.read()).hexdigest()
            if actual != digest:
                raise IOError(f"checksum mismatch in {fname}")

    by_file: Dict[int, List[dict]] = {}
    for entry in manifest["leaves"]:
        by_file.setdefault(entry["file"], []).append(entry)
    path_to_arr: Dict[str, np.ndarray] = {}
    for fidx, entries in by_file.items():
        data = np.load(os.path.join(ckpt, f"arrays_{fidx}.npz"))
        for e in entries:
            path_to_arr[e["path"]] = _from_raw(data[e["key"]], e["dtype"], e["shape"])

    paths, leaves, treedef = _tree_flatten_with_paths(target_tree)
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None else
        [None] * len(leaves))
    out = []
    for path, leaf, sh in zip(paths, leaves, sh_leaves):
        if path not in path_to_arr:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = path_to_arr[path]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        if arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)
