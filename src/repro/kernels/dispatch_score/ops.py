"""Jitted public wrapper for the dispatch window-scoring kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dispatch_score import dispatch_score_pallas, dispatch_score_update_pallas
from .ref import dispatch_score_update_ref, dispatch_scores_ref


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("block_w", "block_e", "block_o",
                                             "interpret"))
def dispatch_scores(demand, presence, *, block_w=256, block_e=128,
                    block_o=512, interpret=False):
    """Window scores demand @ presence.T. demand: [W, O]; presence: [E, O].

    Pads both operands to tile multiples (zero columns/rows score zero) and
    slices the [W, E] result back.  ``interpret=True`` runs the Pallas
    kernel in interpreter mode (CPU correctness path).
    """
    assert demand.ndim == 2 and presence.ndim == 2
    assert demand.shape[1] == presence.shape[1]
    W, E = demand.shape[0], presence.shape[0]
    block_w = min(block_w, max(8, W))
    block_e = min(block_e, max(8, E))
    block_o = min(block_o, max(128, demand.shape[1]))
    d = _pad_to(demand.astype(jnp.float32), block_w, block_o)
    p = _pad_to(presence.astype(jnp.float32), block_e, block_o)
    out = dispatch_score_pallas(d, p, block_w=block_w, block_e=block_e,
                                block_o=block_o, interpret=interpret)
    return out[:W, :E]


@functools.partial(jax.jit, static_argnames=("block_w", "block_e", "block_k",
                                             "interpret"))
def dispatch_score_update(scores, mult, delta, *, block_w=256, block_e=128,
                          block_k=128, interpret=False):
    """Rank-K score update scores + mult @ delta on the resident matrix.

    scores: [W, E]; mult: [W, K]; delta: [K, E].  Pads every operand to tile
    multiples (zero delta rows / mult columns contribute nothing) and slices
    the [W, E] result back.  K == 0 is a no-op (the epoch had no presence
    churn).  ``interpret=True`` runs the Pallas kernel in interpreter mode
    (CPU correctness path).
    """
    assert scores.ndim == mult.ndim == delta.ndim == 2
    assert scores.shape == (mult.shape[0], delta.shape[1])
    assert mult.shape[1] == delta.shape[0]
    W, E = scores.shape
    K = mult.shape[1]
    if K == 0:
        return scores.astype(jnp.float32)
    block_w = min(block_w, max(8, W))
    block_e = min(block_e, max(8, E))
    block_k = min(block_k, max(128, K))
    s = _pad_to(scores.astype(jnp.float32), block_w, block_e)
    m = _pad_to(mult.astype(jnp.float32), block_w, block_k)
    d = _pad_to(delta.astype(jnp.float32), block_k, block_e)
    out = dispatch_score_update_pallas(s, m, d, block_w=block_w,
                                       block_e=block_e, block_k=block_k,
                                       interpret=interpret)
    return out[:W, :E]


__all__ = ["dispatch_scores", "dispatch_scores_ref",
           "dispatch_score_update", "dispatch_score_update_ref"]
