"""Pure-jnp oracle for the dispatch window-scoring kernel."""

from __future__ import annotations

import jax.numpy as jnp


def dispatch_scores_ref(demand, presence):
    """Window scores S = demand @ presence.T in float32.

    demand:   [W, O]  per queued-item object bitmap (multiplicity-weighted)
    presence: [E, O]  per executor cached-object (tier-weighted) matrix
    returns   [W, E]  weighted cache-overlap score per (item, executor)
    """
    return jnp.dot(demand.astype(jnp.float32), presence.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)
