"""Pure-jnp oracle for the dispatch window-scoring kernel."""

from __future__ import annotations

import jax.numpy as jnp


def dispatch_scores_ref(demand, presence):
    """Window scores S = demand @ presence.T in float32.

    demand:   [W, O]  per queued-item object bitmap (multiplicity-weighted)
    presence: [E, O]  per executor cached-object (tier-weighted) matrix
    returns   [W, E]  weighted cache-overlap score per (item, executor)
    """
    return jnp.dot(demand.astype(jnp.float32), presence.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)


def dispatch_score_update_ref(scores, mult, delta):
    """Incremental rank-K score update S' = S + mult @ delta in float32.

    scores: [W, E]  resident score matrix (device copy of Sw)
    mult:   [W, K]  per-item multiplicity of each delta's object column
    delta:  [K, E]  per-delta executor weight change (one-hot rows x dw)
    returns [W, E]  updated scores

    One presence event (object, executor, dw) is a rank-1 term; a coalesced
    epoch of K events is the rank-K product.
    """
    return scores.astype(jnp.float32) + jnp.dot(
        mult.astype(jnp.float32), delta.astype(jnp.float32),
        preferred_element_type=jnp.float32)
