"""Pallas TPU kernel: tiled dispatch window scoring (demand @ presence.T).

The bulk-rescore path of the vectorized dispatch plane is one rectangular
matmul: a [W, O] demand bitmap (W = scheduling window, O = live object
columns) against a [E, O] tier-weighted presence matrix, giving the [W, E]
phase-1/phase-2 score table in one shot.  O is the contraction axis and is
by far the largest extent (every cached object anywhere), so the kernel
tiles it innermost and accumulates in a VMEM scratch block — demand and
presence tiles stream HBM->VMEM once per (i, j) output tile, and the f32
accumulator never leaves VMEM until the last O-step writes it out.

Grid (W/BW, E/BE, O/BO), contraction sequential (minor); both operands are
zero-padded to tile multiples by the wrapper (zeros contribute nothing to
the overlap scores, so padding is semantically free).

The *update* kernel is the incremental companion: instead of rebuilding
S = demand @ presence.T from scratch, it applies a coalesced epoch of K
presence deltas as one rank-K accumulate S' = S + mult @ delta — the same
tiled contraction, but the accumulator initializes from the resident score
tile rather than zero, so the score matrix never leaves the device between
epochs.  K is tiny next to O (an epoch's churn vs every cached object
anywhere), which is the whole point: the device mirror pays O(W*K*E) per
epoch instead of O(W*O*E) per rebuild.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _score_kernel(d_ref, p_ref, o_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        d_ref[...], p_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),   # contract object axis
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _update_kernel(s_ref, m_ref, d_ref, o_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        # Rank-K accumulate: seed the VMEM accumulator from the resident
        # scores instead of zeros — the only difference from _score_kernel.
        acc_ref[...] = s_ref[...]

    acc_ref[...] += jax.lax.dot_general(
        m_ref[...], d_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),   # contract delta axis
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def dispatch_score_update_pallas(scores, mult, delta, *, block_w: int = 256,
                                 block_e: int = 128, block_k: int = 128,
                                 interpret: bool = False):
    """scores: [W, E]; mult: [W, K]; delta: [K, E] -> scores + mult @ delta.

    Shapes must already be padded to the block sizes (see ops.py).  The
    scores tile streams in once per (i, j) output tile (read only at the
    first K-step); mult/delta tiles stream per K-step.
    """
    W, E = scores.shape
    W2, K = mult.shape
    K2, E2 = delta.shape
    assert W == W2 and E == E2 and K == K2
    assert W % block_w == 0 and E % block_e == 0 and K % block_k == 0
    grid = (W // block_w, E // block_e, K // block_k)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_update_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w, block_e), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_w, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_e), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_w, block_e), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((W, E), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w, block_e), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(scores, mult, delta)


def dispatch_score_pallas(demand, presence, *, block_w: int = 256,
                          block_e: int = 128, block_o: int = 512,
                          interpret: bool = False):
    """demand: [W, O] f32; presence: [E, O] f32 -> scores [W, E] f32.

    Shapes must already be padded to the block sizes (see ops.py).
    """
    W, O = demand.shape
    E, O2 = presence.shape
    assert O == O2 and W % block_w == 0 and E % block_e == 0 and O % block_o == 0
    grid = (W // block_w, E // block_e, O // block_o)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_score_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_w, block_o), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_e, block_o), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_w, block_e), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((W, E), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w, block_e), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(demand, presence)
