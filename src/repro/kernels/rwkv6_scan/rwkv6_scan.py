"""Pallas TPU chunked WKV6 kernel (data-dependent decay linear attention).

Grid (B*H, T/CT) with the time axis sequential; the [N, N] state lives in
VMEM scratch across chunk iterations.  Within a chunk the recurrence is
evaluated in matmul form on the MXU:

    L_t   = cumsum(log w)           (per key channel)
    A_ts  = (r_t e^{L_{t-1}}) . (k_s e^{-L_s}),  s < t   (strictly lower)
    out_t = A @ v + (r_t . u k_t) v_t + (r_t e^{L_{t-1}}) @ S
    S'    = diag(e^{L_CT}) S + (k e^{L_CT - L})^T @ v

Numerics: the chunk is processed in SUB-chunks of 16 steps with exact local
log-space exponents — no clamping.  Within 16 steps, |cumsum(log w)| stays
inside f32's exp range for any w >= ~0.003 (per-step decay of 99.7%); below
that, a channel's cross-step contribution is < 0.3% of scale and underflows
harmlessly to 0.  The exact-scan oracle (ref.py) bounds the error in tests,
including a strong-decay case.

VMEM per program (CT=128, N=64): chunks 4 x CT x N f32 = 128 KiB, per-sub
A (16 x 16), S (N x N) 16 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUB = 16  # sub-chunk length: exactness window for strong decays


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, chunk: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)            # [CT, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # [N]
    n = r.shape[-1]

    logw = jnp.log(jnp.maximum(w, 1e-38))
    ti = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)
    lower = si < ti

    def sub_body(i, carry):
        S, out = carry
        start = i * SUB
        rs = jax.lax.dynamic_slice(r, (start, 0), (SUB, n))
        ks = jax.lax.dynamic_slice(k, (start, 0), (SUB, n))
        vs = jax.lax.dynamic_slice(v, (start, 0), (SUB, n))
        lw = jax.lax.dynamic_slice(logw, (start, 0), (SUB, n))
        L = jnp.cumsum(lw, axis=0)              # local reference: exact
        Lprev = L - lw
        a = rs * jnp.exp(Lprev)
        b = ks * jnp.exp(-L)
        A = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        A = jnp.where(lower, A, 0.0)
        intra = jax.lax.dot_general(A, vs, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        diag = jnp.sum(rs * u[None, :] * ks, axis=-1, keepdims=True) * vs
        inter = jax.lax.dot_general(a, S, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        out = jax.lax.dynamic_update_slice(out, intra + diag + inter, (start, 0))
        l_last = L[-1:, :]
        kdec = ks * jnp.exp(l_last - L)
        S = jnp.exp(l_last).T * S + jax.lax.dot_general(
            kdec, vs, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return (S, out)

    S0 = s_ref[...]
    out0 = jnp.zeros((chunk, n), jnp.float32)
    S, out = jax.lax.fori_loop(0, chunk // SUB, sub_body, (S0, out0))
    o_ref[0] = out.astype(o_ref.dtype)
    s_ref[...] = S


def wkv6_pallas(r, k, v, w, u, *, chunk: int = 128, interpret: bool = False):
    """r,k,v,w: [B, T, H, N]; u: [H, N] -> out [B, T, H, N] (f32)."""
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    # flatten (B, H) into the grid's parallel axis; time is sequential
    def flat(a):
        return jnp.moveaxis(a, 2, 1).reshape(B * H, T, N)

    rf, kf, vf, wf = (flat(a) for a in (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    grid = (B * H, T // chunk)
    try:
        cparams = pltpu.CompilerParams(dimension_semantics=("parallel", "arbitrary"))
    except (AttributeError, TypeError):
        cparams = pltpu.TPUCompilerParams(dimension_semantics=("parallel", "arbitrary"))
    out = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, N), lambda bh, it: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, N), lambda bh, it: (bh, it, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return jnp.moveaxis(out.reshape(B, H, T, N), 1, 2)
