"""Jitted public wrapper for the chunked WKV6 kernel."""

from __future__ import annotations

import functools

import jax

from .ref import wkv6_ref
from .rwkv6_scan import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk=128, interpret=False):
    """Chunked WKV6 linear attention. r,k,v,w: [B,T,H,N]; u: [H,N]."""
    assert r.shape == k.shape == v.shape == w.shape
    assert u.shape == r.shape[2:]
    return wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)


__all__ = ["wkv6", "wkv6_ref"]
