"""Oracle for the WKV6 recurrence: exact per-step scan (jnp)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0=None):
    """r,k,v,w: [B, T, H, N]; u: [H, N]; s0: [B, H, N, N] or None.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T;  out_t = r_t (S_{t-1} + u k_t v_t^T).
    Returns (out [B,T,H,N] f32, sT [B,H,N,N] f32).
    """
    B, T, H, N = r.shape
    S = jnp.zeros((B, H, N, N), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    sT, out = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(out, 0, 1), sT
