"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel directory contains <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper), and ref.py (pure-jnp oracle).  Kernels target
TPU; tests run them with interpret=True on CPU against the oracle.
"""

from .dispatch_score.ops import (
    dispatch_score_update,
    dispatch_score_update_ref,
    dispatch_scores,
    dispatch_scores_ref,
)
from .flash_attention.ops import attention_ref, flash_attention
from .moe_gmm.ops import gmm_ref, moe_gmm
from .rglru_scan.ops import rglru_ref, rglru_scan
from .rwkv6_scan.ops import wkv6, wkv6_ref

__all__ = [
    "dispatch_scores", "dispatch_scores_ref",
    "dispatch_score_update", "dispatch_score_update_ref",
    "flash_attention", "attention_ref",
    "moe_gmm", "gmm_ref",
    "rglru_scan", "rglru_ref",
    "wkv6", "wkv6_ref",
]
