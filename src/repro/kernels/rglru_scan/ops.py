"""Jitted public wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import functools

import jax

from .ref import rglru_ref
from .rglru_scan import rglru_pallas


@functools.partial(jax.jit, static_argnames=("block_w", "chunk", "interpret"))
def rglru_scan(a, b, *, block_w=512, chunk=256, interpret=False):
    """Gated diagonal recurrence h_t = a_t h_{t-1} + b_t. a, b: [B, T, W]."""
    assert a.shape == b.shape and a.ndim == 3
    return rglru_pallas(a, b, block_w=block_w, chunk=chunk, interpret=interpret)


__all__ = ["rglru_scan", "rglru_ref"]
