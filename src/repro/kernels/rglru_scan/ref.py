"""Oracle for the RG-LRU diagonal recurrence: exact per-step scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t.  a, b: [B, T, W]; h0: [B, W] or None.

    Returns (y [B, T, W] f32, hT [B, W] f32).
    """
    B, T, W = a.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    xs = (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0))
    hT, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), hT
