"""Pallas TPU RG-LRU scan kernel (diagonal gated linear recurrence).

The recurrence  h_t = a_t * h_{t-1} + b_t  is elementwise per channel — there
is no MXU work; the kernel's value is VMEM residency: gates/inputs stream
HBM->VMEM once per chunk and the hidden state never leaves VMEM (the jnp
lowering writes h to HBM every step of the lax.scan).

Grid (B, W/BW, T/CT), time sequential (minor); h lives in VMEM scratch.
Within a chunk, a fori loop steps rows — VPU-bound by design; the roofline
for this block is the memory term.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, y_ref, h_ref, *, chunk: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        at = a_ref[0, t, :].astype(jnp.float32)
        bt = b_ref[0, t, :].astype(jnp.float32)
        h = at * h + bt
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0])
    h_ref[0] = h


def rglru_pallas(a, b, *, block_w: int = 512, chunk: int = 256,
                 interpret: bool = False):
    """a, b: [B, T, W] -> y: [B, T, W] (f32). h0 = 0."""
    B, T, W = a.shape
    block_w = min(block_w, W)
    chunk = min(chunk, T)
    assert W % block_w == 0 and T % chunk == 0
    grid = (B, W // block_w, T // chunk)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((1, chunk, block_w), lambda ib, iw, it: (ib, it, iw)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w), lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct((B, T, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(a, b)
