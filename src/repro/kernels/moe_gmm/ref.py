"""Oracle for the grouped expert matmul: plain batched einsum."""

from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w):
    """x: [E, C, D]; w: [E, D, F] -> [E, C, F] (f32 accumulation)."""
    return jnp.einsum(
        "ecd,edf->ecf", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)
