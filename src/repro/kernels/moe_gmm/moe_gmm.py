"""Pallas TPU grouped matmul (MoE expert GEMM over the capacity layout).

Grid (E, C/BC, F/BF, D/BD), D as the minor sequential axis accumulating into
a VMEM f32 scratch tile.  This is the kernel behind ``_expert_mlp``'s
einsums: one [BC, BD] x [BD, BF] MXU tile per step, all dims multiples of 128.

VMEM per program: x (BC x BD) + w (BD x BF) bf16 + acc (BC x BF) f32 —
with 256/512/256 tiles: 0.25 + 0.25 + 0.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm_pallas(x, w, *, block_c: int = 256, block_f: int = 256,
               block_d: int = 512, interpret: bool = False):
    """x: [E, C, D]; w: [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c, block_d, block_f = min(block_c, C), min(block_d, D), min(block_f, F)
    assert C % block_c == 0 and D % block_d == 0 and F % block_f == 0
    grid = (E, C // block_c, F // block_f, D // block_d)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, ic, jf, ik: (e, ic, ik)),
            pl.BlockSpec((1, block_d, block_f), lambda e, ic, jf, ik: (e, ik, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda e, ic, jf, ik: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(x, w)
