"""Jitted public wrapper for the grouped expert matmul kernel."""

from __future__ import annotations

import functools

import jax

from .moe_gmm import gmm_pallas
from .ref import gmm_ref


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def moe_gmm(x, w, *, block_c=256, block_f=256, block_d=512, interpret=False):
    """Grouped expert GEMM. x: [E, C, D]; w: [E, D, F] -> [E, C, F]."""
    assert x.ndim == 3 and w.ndim == 3 and x.shape[0] == w.shape[0]
    assert x.shape[2] == w.shape[1]
    return gmm_pallas(x, w, block_c=block_c, block_f=block_f,
                      block_d=block_d, interpret=interpret)


__all__ = ["moe_gmm", "gmm_ref"]
