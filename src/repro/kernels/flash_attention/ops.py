"""Jitted public wrapper for the flash attention kernel."""

from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=0, block_q=512, block_k=512,
                    interpret=False):
    """Tiled online-softmax GQA attention (TPU Pallas; interpret=True on CPU).

    q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D] with H % Hkv == 0.
    """
    assert q.ndim == 4 and k.ndim == 4 and v.ndim == 4
    assert q.shape[2] % k.shape[2] == 0, "H must be a multiple of Hkv"
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


__all__ = ["flash_attention", "attention_ref"]
