"""Pure-jnp oracle for flash attention (GQA, causal, optional window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D]; returns [B, Sq, H, D].

    Direct softmax attention in f32 — the correctness oracle for the Pallas
    kernel (materializes the full score matrix; small shapes only).
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos + (Skv - Sq)  # aligned ends (prefill convention)
    if window:
        ok &= kpos > qpos + (Skv - Sq) - window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)
