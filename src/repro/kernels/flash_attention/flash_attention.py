"""Pallas TPU flash attention: tiled online-softmax GQA with causal skip.

Grid (B, H, Sq/BQ, Skv/BK); the KV axis is the minor (sequential) dimension —
running max/sum/accumulator live in VMEM scratch across KV iterations for a
fixed (b, h, q-block).  Blocks fully above the causal diagonal (and fully
outside the sliding window) are skipped with ``pl.when`` — this is the
schedule that removes the 2x causal FLOP waste of the chunked-jnp lowering
path, and the VMEM residency that removes its HBM score traffic.

VMEM working set per program:  q (BQ x D) + k,v (BK x D each) + acc (BQ x D
f32) + m/l — with BQ=BK=512, D=128 in bf16: 0.5 MiB in + 0.26 MiB scratch,
comfortably inside the ~16 MiB VMEM budget, MXU-aligned (multiples of 128).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q + q_offset     # absolute positions of this q block
    k_start = ik * block_k

    # Block-level skip: fully-masked KV blocks never touch the MXU.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :]
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                            # [BQ, BK]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, *, causal: bool = True, window: int = 0,
    block_q: int = 512, block_k: int = 512, interpret: bool = False,
):
    """q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    q_offset = Skv - Sq  # aligned ends: query i attends to kv <= i + offset

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset,
    )
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    except (AttributeError, TypeError):  # older naming
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // rep, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=cparams,
        interpret=interpret,
    )(q, k, v)
